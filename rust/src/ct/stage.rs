//! §3.3 — compressor-to-stage assignment.
//!
//! Given Algorithm 1's per-column counts, decide at which stage each
//! compressor fires. Two engines:
//!
//! - [`assign_greedy`] — ASAP placement (each stage consumes as many of the
//!   column's remaining compressors as its current population permits).
//!   This realizes the minimum stage count for Algorithm-1 count vectors
//!   (§3.2's optimality argument) in O(stages × columns).
//! - [`assign_ilp`] — the paper's exact ILP (Eq. 6-12) solved with the
//!   in-tree MILP engine; used at small-to-medium widths and by the Fig-13
//!   runtime study. Tests assert it matches the greedy stage count.
//!
//! GOMIL's behaviour (no stage objective) is modelled by
//! [`assign_column_serial`], which compresses each column depth-first and
//! produces the taller trees the paper criticizes.
//!
//! A plan also carries a *timing view*: [`StagePlan::timing`] computes the
//! per-stage arrival snapshot ([`StageTiming`]) once from the plan and the
//! compressor port delays, with **no gate instantiation** — this is how the
//! RL-MUL annealer ([`crate::baselines::rlmul`]) scores candidate trees
//! without dry-running each one into a scratch netlist, and what the
//! exact per-stage profiles recorded by `build_ct` are validated against.

use super::counts::CtCounts;
use crate::ilp::{self, LinExpr, Model, Sense, SolveOptions};
use crate::synth::CompressorTiming;

/// Per-stage arrival-time snapshots of a [`StagePlan`], computed once by
/// [`StagePlan::timing`] from the compressor port delays.
///
/// `snapshots[i][j]` is the model-estimated worst arrival (ns) of column
/// `j`'s population *entering* stage `i`; `snapshots.last()` is the
/// estimated output profile (the Figure-1 trapezoid) before a single gate
/// is instantiated. The model aggregates each column to its worst bit, so
/// it brackets the exact per-bit arrivals that
/// [`super::interconnect::build_ct`] records into
/// [`super::CtOutput::stage_profiles`] during construction.
#[derive(Debug, Clone)]
pub struct StageTiming {
    /// Worst arrival per column entering each stage; `stages + 1` rows.
    pub snapshots: Vec<Vec<f64>>,
}

impl StageTiming {
    /// The estimated CT output arrival profile (last snapshot).
    pub fn final_profile(&self) -> &[f64] {
        self.snapshots.last().map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Number of stages the snapshots span.
    pub fn stages(&self) -> usize {
        self.snapshots.len().saturating_sub(1)
    }
}

/// A stage-by-column placement: `f[i][j]` 3:2s and `h[i][j]` 2:2s fire at
/// stage `i` in column `j`.
#[derive(Debug, Clone)]
pub struct StagePlan {
    /// 3:2 compressors firing at `[stage][column]`.
    pub f: Vec<Vec<usize>>,
    /// 2:2 compressors firing at `[stage][column]`.
    pub h: Vec<Vec<usize>>,
}

impl StagePlan {
    /// Number of stages in the plan.
    pub fn stages(&self) -> usize {
        self.f.len()
    }

    /// Column count of the plan.
    pub fn width(&self) -> usize {
        self.f.first().map_or(0, |r| r.len())
    }

    /// Total `(3:2, 2:2)` compressors over all stages and columns — the
    /// exact gate population the plan will instantiate, used by
    /// [`super::interconnect::build_ct`] to reserve netlist capacity once
    /// up front instead of reallocating per gate.
    pub fn compressor_totals(&self) -> (usize, usize) {
        let fa = self.f.iter().map(|row| row.iter().sum::<usize>()).sum();
        let ha = self.h.iter().map(|row| row.iter().sum::<usize>()).sum();
        (fa, ha)
    }

    /// Compute the per-stage arrival snapshot of this plan over the given
    /// initial column populations (all entering at t = 0 relative to the
    /// PPG outputs). See [`StagePlan::timing_with_arrivals`].
    pub fn timing(&self, initial: &[usize], tm: &CompressorTiming) -> StageTiming {
        self.timing_with_arrivals(initial, &[], tm)
    }

    /// [`StagePlan::timing`] with per-column initial arrival offsets (ns)
    /// — non-uniform PPG outputs, e.g. a Booth matrix. Missing entries
    /// default to 0.
    ///
    /// One pass over `stages × columns` and **no gate instantiation** —
    /// this is how the RL-MUL annealer scores thousands of candidate trees
    /// ([`crate::baselines::rlmul`]) without dry-running each one into a
    /// scratch netlist. The model is the worst-per-column aggregate of the
    /// Eq. 13-16 port delays that `build_ct` applies per bit.
    pub fn timing_with_arrivals(
        &self,
        initial: &[usize],
        arrivals: &[f64],
        tm: &CompressorTiming,
    ) -> StageTiming {
        let w = self.width().max(initial.len());
        let fa_sum = tm.t_as.max(tm.t_bs).max(tm.t_cs);
        let fa_carry = tm.t_ac.max(tm.t_bc).max(tm.t_cc);
        let mut pop: Vec<usize> = initial.to_vec();
        pop.resize(w, 0);
        let mut t_now: Vec<f64> = arrivals.to_vec();
        t_now.resize(w, 0.0);
        let mut snapshots = Vec::with_capacity(self.stages() + 1);
        snapshots.push(t_now.clone());
        // Per-stage scratch reused across iterations (this runs once per
        // *candidate plan* in the annealer loops, so steady-state
        // allocation-freedom matters; only the snapshots themselves are
        // fresh allocations, and those are the function's output).
        let mut pop_next: Vec<usize> = Vec::with_capacity(w);
        let mut t_next: Vec<f64> = Vec::with_capacity(w);
        let mut carry_in: Vec<f64> = Vec::with_capacity(w);
        for i in 0..self.stages() {
            pop_next.clear();
            pop_next.extend_from_slice(&pop);
            t_next.clear();
            t_next.resize(w, 0.0);
            carry_in.clear();
            carry_in.resize(w, 0.0);
            for j in 0..w {
                let (fij, hij) = if j < self.width() { (self.f[i][j], self.h[i][j]) } else { (0, 0) };
                let consumed = 3 * fij + 2 * hij;
                let t_src = t_now[j];
                let mut t_col: f64 = 0.0;
                if pop[j] > consumed {
                    t_col = t_col.max(t_src); // pass-throughs keep their arrival
                }
                if fij > 0 {
                    t_col = t_col.max(t_src + fa_sum);
                    if j + 1 < w {
                        carry_in[j + 1] = carry_in[j + 1].max(t_src + fa_carry);
                    }
                }
                if hij > 0 {
                    t_col = t_col.max(t_src + tm.h_as);
                    if j + 1 < w {
                        carry_in[j + 1] = carry_in[j + 1].max(t_src + tm.h_ac);
                    }
                }
                t_next[j] = t_col;
                pop_next[j] = pop_next[j].saturating_sub(2 * fij + hij);
                if j + 1 < w {
                    pop_next[j + 1] += fij + hij;
                }
            }
            for j in 0..w {
                t_next[j] = t_next[j].max(carry_in[j]);
            }
            std::mem::swap(&mut pop, &mut pop_next);
            std::mem::swap(&mut t_now, &mut t_next);
            snapshots.push(t_now.clone());
        }
        StageTiming { snapshots }
    }

    /// Verify the plan against the counts: totals match (Eq. 6/7), stagewise
    /// populations never go negative and support the placed compressors
    /// (Eq. 8/9), and the final population is ≤ 2 per column.
    pub fn validate(&self, counts: &CtCounts) -> Result<(), String> {
        let w = counts.width();
        let mut tot_f = vec![0usize; w];
        let mut tot_h = vec![0usize; w];
        let mut avail: Vec<usize> = counts.initial.clone();
        for i in 0..self.stages() {
            let mut next = avail.clone();
            for j in 0..w {
                let (fij, hij) = (self.f[i][j], self.h[i][j]);
                if 3 * fij + 2 * hij > avail[j] {
                    return Err(format!(
                        "stage {i} col {j}: {fij}×3:2+{hij}×2:2 exceeds population {}",
                        avail[j]
                    ));
                }
                tot_f[j] += fij;
                tot_h[j] += hij;
                next[j] -= 2 * fij + hij; // 3 consumed, 1 sum emitted (net −2)
                if j + 1 < w {
                    next[j + 1] += fij + hij;
                }
            }
            avail = next;
        }
        if tot_f != counts.f || tot_h != counts.h {
            return Err("stage totals disagree with Algorithm 1 counts".into());
        }
        for (j, &a) in avail.iter().enumerate() {
            if a > 2 {
                return Err(format!("column {j}: {a} bits remain after final stage"));
            }
        }
        Ok(())
    }
}

/// ASAP greedy assignment (minimum stages for Algorithm-1 counts).
pub fn assign_greedy(counts: &CtCounts) -> StagePlan {
    let w = counts.width();
    let mut rem_f = counts.f.clone();
    let mut rem_h = counts.h.clone();
    let mut avail: Vec<usize> = counts.initial.clone();
    let mut plan = StagePlan { f: vec![], h: vec![] };
    let max_stages = 4 * counts.stage_lower_bound() + 8;
    for _ in 0..max_stages {
        if rem_f.iter().all(|&x| x == 0) && rem_h.iter().all(|&x| x == 0) {
            break;
        }
        let mut fi = vec![0usize; w];
        let mut hi = vec![0usize; w];
        let mut next = avail.clone();
        for j in 0..w {
            let mut pop = avail[j];
            let fij = rem_f[j].min(pop / 3);
            pop -= 3 * fij;
            let hij = rem_h[j].min(pop / 2);
            fi[j] = fij;
            hi[j] = hij;
            rem_f[j] -= fij;
            rem_h[j] -= hij;
            next[j] -= 2 * fij + hij;
            if j + 1 < w {
                next[j + 1] += fij + hij;
            }
        }
        plan.f.push(fi);
        plan.h.push(hi);
        avail = next;
    }
    // Release-mode invariant (UFO103 class): a plan that silently drops
    // compressors would build a CT that leaves columns uncompressed, and
    // the server runs release builds — keep this a hard assert.
    assert!(
        rem_f.iter().all(|&x| x == 0) && rem_h.iter().all(|&x| x == 0),
        "greedy stage assignment did not converge"
    );
    plan
}

/// GOMIL-style column-serial assignment: each column is fully compressed by
/// chaining its compressors depth-first (one per stage), ignoring the global
/// stage count — reproducing the baseline's taller CT.
pub fn assign_column_serial(counts: &CtCounts) -> StagePlan {
    let w = counts.width();
    let mut rem_f = counts.f.clone();
    let mut rem_h = counts.h.clone();
    let mut avail: Vec<usize> = counts.initial.clone();
    let mut plan = StagePlan { f: vec![], h: vec![] };
    // Upper bound: total compressors (each fires on its own stage at worst).
    let cap: usize = counts.f.iter().sum::<usize>() + counts.h.iter().sum::<usize>() + 2;
    for _ in 0..cap {
        if rem_f.iter().all(|&x| x == 0) && rem_h.iter().all(|&x| x == 0) {
            break;
        }
        let mut fi = vec![0usize; w];
        let mut hi = vec![0usize; w];
        let mut next = avail.clone();
        for j in 0..w {
            // at most ONE compressor per column per stage (serial chains)
            let mut pop = avail[j];
            if rem_f[j] > 0 && pop >= 3 {
                fi[j] = 1;
                rem_f[j] -= 1;
                pop -= 3;
                next[j] -= 2;
                if j + 1 < w {
                    next[j + 1] += 1;
                }
            } else if rem_h[j] > 0 && pop >= 2 {
                hi[j] = 1;
                rem_h[j] -= 1;
                next[j] -= 1;
                if j + 1 < w {
                    next[j + 1] += 1;
                }
            }
            let _ = pop;
        }
        plan.f.push(fi);
        plan.h.push(hi);
        avail = next;
    }
    plan
}

/// Exact §3.3 ILP (Eq. 6-12). Returns the plan and the solver's node count
/// (reported by the Fig-13 bench). Falls back to the greedy plan if the
/// solver hits its limits without an incumbent.
pub fn assign_ilp(counts: &CtCounts, opts: &SolveOptions) -> (StagePlan, u64) {
    assign_ilp_with(counts, assign_greedy(counts), opts)
}

/// [`assign_ilp`] over a caller-provided greedy plan, so callers that
/// already computed one (and its [`StageTiming`] snapshot) don't pay for
/// it twice: the greedy plan seeds the ILP's stage horizon and serves as
/// the fallback incumbent.
pub fn assign_ilp_with(counts: &CtCounts, greedy: StagePlan, opts: &SolveOptions) -> (StagePlan, u64) {
    let w = counts.width();
    let stage_max = greedy.stages().max(1); // optimum is ≤ greedy
    let mut m = Model::new();

    // Variables.
    let fmax = *counts.f.iter().max().unwrap_or(&0) as f64;
    let hmax = *counts.h.iter().max().unwrap_or(&0) as f64;
    let f_v: Vec<Vec<_>> = (0..stage_max)
        .map(|i| (0..w).map(|j| m.int(format!("f{i}_{j}"), 0.0, fmax)).collect())
        .collect();
    let h_v: Vec<Vec<_>> = (0..stage_max)
        .map(|i| (0..w).map(|j| m.int(format!("h{i}_{j}"), 0.0, hmax)).collect())
        .collect();
    let pp_v: Vec<Vec<_>> = (0..=stage_max)
        .map(|i| (0..w).map(|j| m.cont(format!("pp{i}_{j}"), 0.0, 1e4)).collect())
        .collect();
    let y_v: Vec<Vec<_>> = (0..stage_max)
        .map(|i| (0..w).map(|j| m.bin(format!("y{i}_{j}"))).collect())
        .collect();
    let s_v = m.cont("S", 0.0, stage_max as f64);
    let big = 1e3;

    for j in 0..w {
        // Eq. 6/7: totals match Algorithm 1.
        let fsum: Vec<_> = (0..stage_max).map(|i| (f_v[i][j], 1.0)).collect();
        m.constrain(LinExpr::of(&fsum), Sense::Eq, counts.f[j] as f64);
        let hsum: Vec<_> = (0..stage_max).map(|i| (h_v[i][j], 1.0)).collect();
        m.constrain(LinExpr::of(&hsum), Sense::Eq, counts.h[j] as f64);
        // Initial populations.
        m.constrain(LinExpr::of(&[(pp_v[0][j], 1.0)]), Sense::Eq, counts.initial[j] as f64);
    }
    for i in 0..stage_max {
        for j in 0..w {
            // Eq. 8: population recurrence.
            let mut e = LinExpr::new();
            e.add(pp_v[i + 1][j], 1.0);
            e.add(pp_v[i][j], -1.0);
            e.add(f_v[i][j], 2.0);
            e.add(h_v[i][j], 1.0);
            if j > 0 {
                e.add(f_v[i][j - 1], -1.0);
                e.add(h_v[i][j - 1], -1.0);
            }
            m.constrain(e, Sense::Eq, 0.0);
            // Eq. 9: compressors fit the population.
            m.constrain(
                LinExpr::of(&[(f_v[i][j], 3.0), (h_v[i][j], 2.0), (pp_v[i][j], -1.0)]),
                Sense::Le,
                0.0,
            );
            // Eq. 10/11: stage-use indicators.
            m.constrain(
                LinExpr::of(&[(s_v, 1.0), (y_v[i][j], -((i + 1) as f64))]),
                Sense::Ge,
                0.0,
            );
            m.constrain(
                LinExpr::of(&[(y_v[i][j], big), (f_v[i][j], -1.0), (h_v[i][j], -1.0)]),
                Sense::Ge,
                0.0,
            );
        }
    }
    // Final populations ≤ 2 (the two-row output requirement).
    for j in 0..w {
        m.constrain(LinExpr::of(&[(pp_v[stage_max][j], 1.0)]), Sense::Le, 2.0);
    }
    m.minimize(LinExpr::of(&[(s_v, 1.0)]));

    let sol = ilp::solve(&m, opts);
    if !sol.ok() {
        return (greedy, sol.nodes);
    }
    let used = sol.value(s_v).round() as usize;
    let mut plan = StagePlan {
        f: vec![vec![0; w]; used.max(1)],
        h: vec![vec![0; w]; used.max(1)],
    };
    for i in 0..used.max(1).min(stage_max) {
        for j in 0..w {
            plan.f[i][j] = sol.int_value(f_v[i][j]) as usize;
            plan.h[i][j] = sol.int_value(h_v[i][j]) as usize;
        }
    }
    // Always-on lint guard on the candidate-evaluation loop: a rounded
    // MILP incumbent can be plausible-but-malformed, so the cheap UFO1xx
    // checks vet it before it replaces the known-good greedy plan.
    if !crate::lint::check_plan_counts(counts, &plan).is_empty() {
        return (greedy, sol.nodes);
    }
    (plan, sol.nodes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mult_counts(n: usize) -> CtCounts {
        let pp: Vec<usize> = (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
        CtCounts::from_populations(&pp)
    }

    #[test]
    fn greedy_is_valid_and_hits_lower_bound() {
        for n in [3, 4, 8, 16, 32] {
            let c = mult_counts(n);
            let plan = assign_greedy(&c);
            plan.validate(&c).unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert_eq!(
                plan.stages(),
                c.stage_lower_bound(),
                "n={n}: greedy {} vs bound {}",
                plan.stages(),
                c.stage_lower_bound()
            );
        }
    }

    #[test]
    fn column_serial_is_valid_but_taller() {
        let c = mult_counts(8);
        let serial = assign_column_serial(&c);
        serial.validate(&c).unwrap();
        let greedy = assign_greedy(&c);
        assert!(
            serial.stages() > greedy.stages(),
            "serial {} vs greedy {}",
            serial.stages(),
            greedy.stages()
        );
    }

    #[test]
    fn ilp_matches_greedy_optimum_small() {
        for n in [3, 4] {
            let c = mult_counts(n);
            let opts = SolveOptions {
                time_limit: std::time::Duration::from_secs(20),
                ..Default::default()
            };
            let (plan, _) = assign_ilp(&c, &opts);
            plan.validate(&c).unwrap();
            assert_eq!(plan.stages(), assign_greedy(&c).stages(), "n={n}");
        }
    }

    #[test]
    fn stage_timing_snapshot_computed_once_matches_plan_shape() {
        let c = mult_counts(8);
        let plan = assign_greedy(&c);
        let tm = crate::synth::CompressorTiming::from_lib(&crate::ir::CellLib::nangate45());
        let st = plan.timing(&c.initial, &tm);
        assert_eq!(st.stages(), plan.stages());
        assert_eq!(st.snapshots.len(), plan.stages() + 1);
        assert!(st.snapshots[0].iter().all(|&t| t == 0.0), "inputs enter at t = 0");
        let prof = st.final_profile();
        assert_eq!(prof.len(), c.width());
        let max = prof.iter().copied().fold(0.0f64, f64::max);
        assert!(max > 0.0);
        // The model profile is the Figure-1 trapezoid: the peak sits in
        // the middle of the word, not at either end.
        let peak = prof.iter().position(|&t| t == max).unwrap();
        assert!(peak > 0 && peak < prof.len() - 1, "peak {peak} of {}", prof.len());
    }

    #[test]
    fn mac_shapes_assign_cleanly() {
        for n in [4, 8] {
            let mut pp: Vec<usize> =
                (0..2 * n - 1).map(|j| n.min(j + 1).min(2 * n - 1 - j)).collect();
            pp.push(0);
            for p in pp.iter_mut() {
                *p += 1;
            }
            let c = CtCounts::from_populations(&pp);
            let plan = assign_greedy(&c);
            plan.validate(&c).unwrap();
        }
    }
}
