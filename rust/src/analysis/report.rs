//! The [`AnalysisReport`] that travels on compile artifacts and over the
//! wire — the abstract-interpretation counterpart of
//! [`crate::lint::LintReport`].
//!
//! The report is the *summary* of an [`crate::analysis::AnalysisOutcome`]:
//! proven-constant counts, fixpoint iteration counts, per-group word
//! intervals and the UFO4xx diagnostics. The full per-node vectors stay
//! in memory only — persisting them would bloat disk-cache entries by
//! O(nodes) per design for data any reader can recompute
//! deterministically. Rendering is a pure function of the analysis result
//! (worker-count independent — `rust/tests/analysis.rs` pins 1/2/4/7
//! workers to byte-identical JSON), and interval bounds serialize as
//! decimal strings because `u128` exceeds JSON number precision.

use crate::lint::{Diagnostic, Severity};
use crate::util::Json;

/// Summary of one output weight group's proven interval.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupSummary {
    /// Digit-stripped output-name prefix.
    pub name: String,
    /// Output registration ordinal of the group's LSB.
    pub output: usize,
    /// Number of bits in the group.
    pub bits: usize,
    /// Proven lower bound of the little-endian word.
    pub lo: u128,
    /// Proven upper bound of the little-endian word.
    pub hi: u128,
}

impl GroupSummary {
    /// Wire/persistence form:
    /// `{"bits":…,"hi":"…","lo":"…","name":…,"output":…}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bits", Json::num(self.bits as f64)),
            ("hi", Json::str(self.hi.to_string())),
            ("lo", Json::str(self.lo.to_string())),
            ("name", Json::str(&self.name)),
            ("output", Json::num(self.output as f64)),
        ])
    }

    /// Parse the [`GroupSummary::to_json`] form back.
    pub fn from_json(j: &Json) -> Result<GroupSummary, String> {
        let num = |k: &str| -> Result<usize, String> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .map(|v| v as usize)
                .ok_or_else(|| format!("group: missing number field '{k}'"))
        };
        let word = |k: &str| -> Result<u128, String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("group: missing string field '{k}'"))?
                .parse::<u128>()
                .map_err(|e| format!("group: bad {k}: {e}"))
        };
        Ok(GroupSummary {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("group: missing string field 'name'")?
                .to_string(),
            output: num("output")?,
            bits: num("bits")?,
            lo: word("lo")?,
            hi: word("hi")?,
        })
    }
}

/// The persisted outcome of one abstract-interpretation run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AnalysisReport {
    /// Netlist nodes analyzed.
    pub nodes: usize,
    /// Gates and registers proven constant 0.
    pub proven_zero: usize,
    /// Gates and registers proven constant 1.
    pub proven_one: usize,
    /// Full sweeps the ternary register fixpoint needed.
    pub tern_sweeps: usize,
    /// Full sweeps the probability register fixpoint needed.
    pub prob_sweeps: usize,
    /// Correlation-depth cap the probability domain ran with.
    pub correlation_depth: usize,
    /// Mean static switching activity over gate nodes.
    pub mean_activity: f64,
    /// Proven word interval per output weight group.
    pub groups: Vec<GroupSummary>,
    /// UFO4xx findings, in emission order (401, 402, 403, 404, 405).
    pub diagnostics: Vec<Diagnostic>,
}

impl AnalysisReport {
    /// Gates and registers proven constant (either polarity).
    pub fn proven_const(&self) -> usize {
        self.proven_zero + self.proven_one
    }

    /// No findings at all.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Worst severity present, or `None` when clean.
    pub fn max_severity(&self) -> Option<Severity> {
        self.diagnostics.iter().map(|d| d.severity).max()
    }

    /// Number of findings at exactly `sev`.
    pub fn count(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == sev).count()
    }

    /// Whether any finding is at or above `deny`.
    pub fn denies(&self, deny: Severity) -> bool {
        self.max_severity().is_some_and(|m| m >= deny)
    }

    /// Wire/persistence form (all fields, sorted keys under
    /// [`Json::render`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("correlation_depth", Json::num(self.correlation_depth as f64)),
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
            ),
            ("groups", Json::Arr(self.groups.iter().map(GroupSummary::to_json).collect())),
            ("mean_activity", Json::num(self.mean_activity)),
            ("nodes", Json::num(self.nodes as f64)),
            ("prob_sweeps", Json::num(self.prob_sweeps as f64)),
            ("proven_one", Json::num(self.proven_one as f64)),
            ("proven_zero", Json::num(self.proven_zero as f64)),
            ("tern_sweeps", Json::num(self.tern_sweeps as f64)),
        ])
    }

    /// Parse the [`AnalysisReport::to_json`] form back.
    pub fn from_json(j: &Json) -> Result<AnalysisReport, String> {
        let num = |k: &str| -> Result<usize, String> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .map(|v| v as usize)
                .ok_or_else(|| format!("analysis report: missing number field '{k}'"))
        };
        let diagnostics = j
            .get("diagnostics")
            .and_then(|v| v.as_arr())
            .ok_or("analysis report: missing 'diagnostics' array")?
            .iter()
            .map(Diagnostic::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let groups = j
            .get("groups")
            .and_then(|v| v.as_arr())
            .ok_or("analysis report: missing 'groups' array")?
            .iter()
            .map(GroupSummary::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(AnalysisReport {
            nodes: num("nodes")?,
            proven_zero: num("proven_zero")?,
            proven_one: num("proven_one")?,
            tern_sweeps: num("tern_sweeps")?,
            prob_sweeps: num("prob_sweeps")?,
            correlation_depth: num("correlation_depth")?,
            mean_activity: j
                .get("mean_activity")
                .and_then(|v| v.as_f64())
                .ok_or("analysis report: missing number field 'mean_activity'")?,
            groups,
            diagnostics,
        })
    }

    /// Wire summary used by the server's `analyze` command and the CLI's
    /// `--json` mode:
    /// `{"clean":…,"counts":{…},"diagnostics":[…],"groups":[…],
    /// "mean_activity":…,"proven_const":…}`.
    pub fn summary_json(&self) -> Json {
        let counts = Json::obj(vec![
            ("error", Json::num(self.count(Severity::Error) as f64)),
            ("info", Json::num(self.count(Severity::Info) as f64)),
            ("warning", Json::num(self.count(Severity::Warning) as f64)),
        ]);
        Json::obj(vec![
            ("clean", Json::Bool(self.is_clean())),
            ("counts", counts),
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(Diagnostic::to_json).collect()),
            ),
            ("groups", Json::Arr(self.groups.iter().map(GroupSummary::to_json).collect())),
            ("mean_activity", Json::num(self.mean_activity)),
            ("proven_const", Json::num(self.proven_const() as f64)),
        ])
    }
}

impl std::fmt::Display for AnalysisReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} nodes, {} proven constant ({} zero / {} one), mean activity {:.4}, \
             sweeps tern {} / prob {}",
            self.nodes,
            self.proven_const(),
            self.proven_zero,
            self.proven_one,
            self.mean_activity,
            self.tern_sweeps,
            self.prob_sweeps
        )?;
        for g in &self.groups {
            write!(f, "\n  group {}[{}] in [{}, {}]", g.name, g.bits, g.lo, g.hi)?;
        }
        for d in &self.diagnostics {
            write!(f, "\n  {d}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{Locus, UFO401};

    #[test]
    fn report_roundtrips_bytewise() {
        let rep = AnalysisReport {
            nodes: 42,
            proven_zero: 3,
            proven_one: 1,
            tern_sweeps: 2,
            prob_sweeps: 5,
            correlation_depth: 2,
            mean_activity: 0.375,
            groups: vec![GroupSummary {
                name: "p".to_string(),
                output: 0,
                bits: 16,
                lo: 1,
                hi: (1u128 << 100) + 7,
            }],
            diagnostics: vec![Diagnostic::new(UFO401, Locus::Output(3), "proven 0")],
        };
        let back = AnalysisReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back, rep);
        assert_eq!(back.to_json().render(), rep.to_json().render());
        assert_eq!(rep.proven_const(), 4);
        assert!(!rep.is_clean());
        assert!(rep.denies(Severity::Warning));
        assert!(!rep.denies(Severity::Error));
        assert_eq!(rep.count(Severity::Warning), 1);
    }

    #[test]
    fn clean_default_report() {
        let rep = AnalysisReport::default();
        assert!(rep.is_clean());
        assert_eq!(rep.max_severity(), None);
        assert!(!rep.denies(Severity::Info));
        let back = AnalysisReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back, rep);
    }
}
