//! The one request type of the unified API.
//!
//! A [`DesignRequest`] is a serializable, *canonicalizable* description of
//! anything the framework can synthesize: a raw multiplier/MAC spec, a
//! baseline-method design, or a functional module (FIR stage, systolic
//! PE). Canonicalization rewrites a request into the normal form the
//! engine actually compiles — e.g. a non-search method request lowers to
//! the exact [`MultiplierSpec`] it denotes, and fields that cannot affect
//! the result (an FDC model attached to a regular CPA choice) are zeroed —
//! so equivalent requests share one [`fingerprint`](DesignRequest::fingerprint)
//! and therefore one cache entry.

use crate::baselines::{spec_for_fmt, BaselineBudget, Method};
use crate::cpa::{FdcModel, PrefixStructure};
use crate::ct::{CtArchitecture, OrderStrategy, StagePlan};
use crate::multiplier::{CpaChoice, MultiplierSpec, Strategy};
use crate::ppg::{OperandFormat, PpgKind, Signedness};
use crate::util::Json;
use crate::Result;
use anyhow::{anyhow, bail};
use std::fmt;

/// Accumulator handling for multiplier-family requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacMode {
    /// Plain multiplier.
    None,
    /// §2.3 fused MAC: accumulator rows injected into the CT.
    Fused,
    /// Conventional MAC: multiply, then a separate CPA.
    Separate,
}

/// A fully explicit multiplier/MAC specification (mirror of
/// [`MultiplierSpec`], in serializable form).
#[derive(Debug, Clone)]
pub struct MulRequest {
    /// Operand bit width (the wider operand for rectangular formats).
    pub n: usize,
    /// Operand format (signedness + per-operand widths). Serialization
    /// omits the field when it equals the unsigned square `n×n` default,
    /// keeping pre-format request fingerprints byte-stable.
    pub format: OperandFormat,
    /// Partial-product generator (AND array / radix-4 Booth).
    pub ppg: PpgKind,
    /// Compressor-tree architecture.
    pub ct: CtArchitecture,
    /// Interconnect-order override (`None` = the architecture's default).
    pub order: Option<OrderStrategy>,
    /// Custom stage plan (RL-MUL searched trees); `None` = derived.
    pub ct_plan: Option<StagePlan>,
    /// Carry-propagate adder choice.
    pub cpa: CpaChoice,
    /// Synthesis strategy preset (area / timing / trade-off).
    pub strategy: Strategy,
    /// Accumulator handling.
    pub mac: MacMode,
    /// FDC timing model driving CPA optimization.
    pub fdc: FdcModel,
    /// Register ranks cut into the datapath (`0` = combinational).
    /// Serialization omits the field when `0`, keeping every
    /// pre-pipeline request fingerprint byte-stable.
    pub pipeline_stages: usize,
}

/// A baseline-method design request (the coordinator's sweep axis).
#[derive(Debug, Clone)]
pub struct MethodRequest {
    /// Which method family (UFO-MAC or a baseline) to synthesize.
    pub method: Method,
    /// Operand bit width (method designs are square `n×n`).
    pub n: usize,
    /// Operand signedness (the coordinator's format sweep axis).
    /// Serialization omits the field when `Unsigned`, keeping pre-format
    /// request fingerprints byte-stable.
    pub signedness: Signedness,
    /// Synthesis strategy preset.
    pub strategy: Strategy,
    /// Fused-MAC variant (baseline methods fuse; `separate` is reached via
    /// an explicit [`MulRequest`]).
    pub mac: bool,
    /// Search budget for the search-based baselines (RL-MUL).
    pub budget: BaselineBudget,
}

/// Which functional module a [`ModuleRequest`] targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModuleKind {
    /// 5-tap transposed-FIR pipeline stage (Table 1).
    Fir,
    /// 16×16 systolic-array processing element (Table 2).
    Systolic,
}

/// A module-level request: the stage/PE netlist plus a clocked report.
#[derive(Debug, Clone)]
pub struct ModuleRequest {
    /// Which module wraps the inner multiplier/MAC.
    pub module: ModuleKind,
    /// Method family of the inner design.
    pub method: Method,
    /// Operand bit width of the inner design.
    pub n: usize,
    /// Synthesis strategy preset of the inner design.
    pub strategy: Strategy,
    /// Clock target for the WNS/power report.
    pub freq_hz: f64,
}

/// The single request type compiled by [`crate::api::SynthEngine`].
///
/// | old entry point | request form |
/// |---|---|
/// | `MultiplierSpec::build` | [`DesignRequest::Multiplier`] |
/// | `baselines::build_design` | [`DesignRequest::Method`] |
/// | `modules::fir_report` / `build_fir_stage` | [`DesignRequest::Module`] (`Fir`) |
/// | `modules::systolic_report` / `build_pe` | [`DesignRequest::Module`] (`Systolic`) |
/// | `coordinator::evaluate_point` | [`DesignRequest::Method`] |
///
/// Requests round-trip through JSON (the server's wire form, see
/// `PROTOCOL.md`) with a stable content fingerprint:
///
/// ```
/// use ufo_mac::api::DesignRequest;
///
/// let wire = r#"{"kind":"method","method":"ufo","n":8,"strategy":"tradeoff","mac":false}"#;
/// let req = DesignRequest::parse(wire)?;
/// let back = DesignRequest::parse(&req.to_json_string())?;
/// assert_eq!(req.fingerprint(), back.fingerprint());
/// assert_eq!(req.width(), 8);
/// # Ok::<(), anyhow::Error>(())
/// ```
#[derive(Debug, Clone)]
pub enum DesignRequest {
    /// Fully explicit multiplier/MAC specification.
    Multiplier(MulRequest),
    /// Baseline-method shorthand (the coordinator's sweep axis).
    Method(MethodRequest),
    /// Functional-module request (FIR stage / systolic PE).
    Module(ModuleRequest),
}

/// 128-bit content hash of a request's canonical form (FNV-1a).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u128);

impl Fingerprint {
    const OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
    const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;

    /// FNV-1a over raw bytes.
    pub fn of_bytes(bytes: &[u8]) -> Fingerprint {
        let mut h = Self::OFFSET;
        for &b in bytes {
            h ^= u128::from(b);
            h = h.wrapping_mul(Self::PRIME);
        }
        Fingerprint(h)
    }

    /// Shard selector for the design cache.
    pub fn shard(&self, shards: usize) -> usize {
        // High bits mix better than low bits for FNV.
        ((self.0 >> 64) as usize) % shards.max(1)
    }
}

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

impl fmt::Debug for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Fingerprint({:032x})", self.0)
    }
}

impl DesignRequest {
    // ---------------------------------------------------------------
    // Constructors.
    // ---------------------------------------------------------------

    /// UFO-MAC multiplier with default knobs (the old
    /// `MultiplierSpec::new(n)`).
    pub fn multiplier(n: usize) -> DesignRequest {
        DesignRequest::from_spec(&MultiplierSpec::new(n))
    }

    /// A baseline-method design (the old `baselines::build_design`).
    pub fn method(method: Method, n: usize, strategy: Strategy, mac: bool) -> DesignRequest {
        DesignRequest::method_with(method, n, strategy, mac, Signedness::Unsigned)
    }

    /// [`DesignRequest::method`] with an explicit operand signedness.
    pub fn method_with(
        method: Method,
        n: usize,
        strategy: Strategy,
        mac: bool,
        signedness: Signedness,
    ) -> DesignRequest {
        DesignRequest::Method(MethodRequest {
            method,
            n,
            signedness,
            strategy,
            mac,
            budget: BaselineBudget::default(),
        })
    }

    /// A FIR pipeline-stage request (the old `modules::fir_report`).
    pub fn fir(method: Method, n: usize, strategy: Strategy, freq_hz: f64) -> DesignRequest {
        DesignRequest::Module(ModuleRequest { module: ModuleKind::Fir, method, n, strategy, freq_hz })
    }

    /// A systolic-PE request (the old `modules::systolic_report`).
    pub fn systolic(method: Method, n: usize, strategy: Strategy, freq_hz: f64) -> DesignRequest {
        DesignRequest::Module(ModuleRequest {
            module: ModuleKind::Systolic,
            method,
            n,
            strategy,
            freq_hz,
        })
    }

    /// Capture an explicit [`MultiplierSpec`] (the old `spec.build()`).
    ///
    /// A request is valid by construction ([`MacMode`] holds exactly one
    /// accumulator mode), so the one invalid spec state —
    /// `fused_mac && separate_mac` — cannot be represented; this capture
    /// resolves it to [`MacMode::Fused`]. `MultiplierSpec::build`
    /// rejects that state before converting; callers constructing specs
    /// by hand should do the same.
    pub fn from_spec(spec: &MultiplierSpec) -> DesignRequest {
        DesignRequest::Multiplier(MulRequest {
            n: spec.n,
            format: spec.format,
            ppg: spec.ppg,
            ct: spec.ct,
            order: spec.order_override,
            ct_plan: spec.ct_plan.clone(),
            cpa: spec.cpa,
            strategy: spec.strategy,
            mac: if spec.fused_mac {
                MacMode::Fused
            } else if spec.separate_mac {
                MacMode::Separate
            } else {
                MacMode::None
            },
            fdc: spec.fdc_model,
            pipeline_stages: spec.pipeline_stages,
        })
    }

    // ---------------------------------------------------------------
    // Canonicalization + fingerprint.
    // ---------------------------------------------------------------

    /// Rewrite into the engine's normal form. Idempotent.
    ///
    /// - A [`MethodRequest`] for a deterministic method (everything except
    ///   RL-MUL's annealing search) lowers to the exact [`MulRequest`] it
    ///   denotes, so `Method(UfoMac, 8, …)` and the equivalent explicit
    ///   spec share a cache entry. RL-MUL requests stay method-form (the
    ///   search is part of the request) with their budget retained.
    /// - Dead fields are normalized so they cannot split the cache: the
    ///   FDC model and the strategy under a regular CPA choice (both are
    ///   only read by the profile-optimized CPA synthesis), and the CT
    ///   architecture when an explicit `ct_plan` overrides it.
    pub fn canonical(&self) -> DesignRequest {
        match self {
            DesignRequest::Multiplier(m) => {
                let mut m = m.clone();
                // The reporting width is derived state.
                m.n = m.format.max_bits();
                if matches!(m.cpa, CpaChoice::Regular(_)) {
                    m.fdc = FdcModel { k: [0.0; 4], b: 0.0 };
                    m.strategy = Strategy::TradeOff;
                }
                if m.ct_plan.is_some() {
                    m.ct = CtArchitecture::UfoMac;
                }
                DesignRequest::Multiplier(m)
            }
            DesignRequest::Method(mr) => {
                if mr.method == Method::RlMul {
                    DesignRequest::Method(mr.clone())
                } else {
                    let fmt = OperandFormat {
                        signedness: mr.signedness,
                        a_bits: mr.n,
                        b_bits: mr.n,
                    };
                    let spec = spec_for_fmt(mr.method, fmt, mr.strategy, mr.mac);
                    DesignRequest::from_spec(&spec).canonical()
                }
            }
            DesignRequest::Module(m) => DesignRequest::Module(m.clone()),
        }
    }

    /// Stable content hash over the canonical JSON form.
    pub fn fingerprint(&self) -> Fingerprint {
        self.canonical().fingerprint_of_canonical()
    }

    /// Fingerprint of `self` *as-is*, assuming it is already canonical —
    /// the engine's fast path after it has canonicalized once. Calling
    /// this on a non-canonical request gives a hash that will never match
    /// the cache; use [`Self::fingerprint`] unless you hold the output of
    /// [`Self::canonical`].
    pub fn fingerprint_of_canonical(&self) -> Fingerprint {
        Fingerprint::of_bytes(self.to_json().render().as_bytes())
    }

    /// Operand width of the requested design.
    pub fn width(&self) -> usize {
        match self {
            DesignRequest::Multiplier(m) => m.n,
            DesignRequest::Method(m) => m.n,
            DesignRequest::Module(m) => m.n,
        }
    }

    // ---------------------------------------------------------------
    // JSON round-trip.
    // ---------------------------------------------------------------

    /// Serialize (stable key order; `u64` fields travel as decimal strings
    /// to stay lossless).
    pub fn to_json(&self) -> Json {
        match self {
            DesignRequest::Multiplier(m) => {
                let mut fields = vec![
                    ("kind", Json::str("multiplier")),
                    ("n", Json::num(m.n as f64)),
                    ("ppg", Json::str(ppg_key(m.ppg))),
                    ("ct", Json::str(ct_key(m.ct))),
                    (
                        "order",
                        match m.order {
                            None => Json::Null,
                            Some(o) => Json::str(order_key(o)),
                        },
                    ),
                    ("cpa", Json::str(cpa_key(&m.cpa))),
                    ("strategy", Json::str(strategy_key(m.strategy))),
                    ("mac", Json::str(mac_key(m.mac))),
                    (
                        "fdc",
                        Json::obj(vec![
                            ("k", Json::arr(m.fdc.k.iter().map(|&x| Json::num(x)).collect())),
                            ("b", Json::num(m.fdc.b)),
                        ]),
                    ),
                ];
                fields.push((
                    "ct_plan",
                    match &m.ct_plan {
                        None => Json::Null,
                        Some(p) => plan_to_json(p),
                    },
                ));
                // Pre-format requests rendered no `format` key; omitting
                // the default keeps their fingerprints byte-stable.
                if m.format != OperandFormat::unsigned(m.n) {
                    fields.push((
                        "format",
                        Json::obj(vec![
                            ("a_bits", Json::num(m.format.a_bits as f64)),
                            ("b_bits", Json::num(m.format.b_bits as f64)),
                            ("signed", Json::Bool(m.format.is_signed())),
                        ]),
                    ));
                }
                // Combinational requests rendered no `pipeline_stages`
                // key before the sequential IR existed; omitting the 0
                // default keeps their fingerprints byte-stable.
                if m.pipeline_stages > 0 {
                    fields.push(("pipeline_stages", Json::num(m.pipeline_stages as f64)));
                }
                Json::obj(fields)
            }
            DesignRequest::Method(m) => {
                let mut fields = vec![
                    ("kind", Json::str("method")),
                    ("method", Json::str(m.method.key())),
                    ("n", Json::num(m.n as f64)),
                    ("strategy", Json::str(strategy_key(m.strategy))),
                    ("mac", Json::Bool(m.mac)),
                    ("rlmul_iters", Json::num(m.budget.rlmul_iters as f64)),
                    ("seed", Json::str(m.budget.seed.to_string())),
                ];
                if m.signedness == Signedness::Signed {
                    fields.push(("signedness", Json::str("signed")));
                }
                Json::obj(fields)
            }
            DesignRequest::Module(m) => Json::obj(vec![
                (
                    "kind",
                    Json::str(match m.module {
                        ModuleKind::Fir => "fir",
                        ModuleKind::Systolic => "systolic",
                    }),
                ),
                ("method", Json::str(m.method.key())),
                ("n", Json::num(m.n as f64)),
                ("strategy", Json::str(strategy_key(m.strategy))),
                ("freq_hz", Json::num(m.freq_hz)),
            ]),
        }
    }

    /// Render to a JSON string.
    pub fn to_json_string(&self) -> String {
        self.to_json().render()
    }

    /// Parse a request back from [`Self::to_json`] output.
    pub fn from_json(j: &Json) -> Result<DesignRequest> {
        let kind = str_field(j, "kind")?;
        match kind {
            "multiplier" => {
                let order = match j.get("order") {
                    None | Some(Json::Null) => None,
                    Some(o) => Some(parse_order(
                        o.as_str().ok_or_else(|| anyhow!("order must be a string"))?,
                    )?),
                };
                let ct_plan = match j.get("ct_plan") {
                    None | Some(Json::Null) => None,
                    Some(p) => Some(plan_from_json(p)?),
                };
                let fdc = {
                    let f = j.get("fdc").ok_or_else(|| anyhow!("missing field 'fdc'"))?;
                    let ks = f
                        .get("k")
                        .and_then(|k| k.as_arr())
                        .ok_or_else(|| anyhow!("fdc.k must be an array"))?;
                    if ks.len() != 4 {
                        bail!("fdc.k must have 4 entries");
                    }
                    let mut k = [0.0f64; 4];
                    for (i, v) in ks.iter().enumerate() {
                        k[i] = v.as_f64().ok_or_else(|| anyhow!("fdc.k[{i}] must be a number"))?;
                    }
                    let b = f
                        .get("b")
                        .and_then(|b| b.as_f64())
                        .ok_or_else(|| anyhow!("fdc.b must be a number"))?;
                    FdcModel { k, b }
                };
                let n = usize_field(j, "n")?;
                // Missing `format` means a pre-format (unsigned square)
                // request — the backward-compatible default.
                let format = match j.get("format") {
                    None | Some(Json::Null) => OperandFormat::unsigned(n),
                    Some(f) => OperandFormat {
                        signedness: if f
                            .get("signed")
                            .and_then(|b| b.as_bool())
                            .ok_or_else(|| anyhow!("format.signed must be a bool"))?
                        {
                            Signedness::Signed
                        } else {
                            Signedness::Unsigned
                        },
                        a_bits: usize_field(f, "a_bits")?,
                        b_bits: usize_field(f, "b_bits")?,
                    },
                };
                Ok(DesignRequest::Multiplier(MulRequest {
                    n,
                    format,
                    ppg: parse_ppg(str_field(j, "ppg")?)?,
                    ct: parse_ct(str_field(j, "ct")?)?,
                    order,
                    ct_plan,
                    cpa: parse_cpa(str_field(j, "cpa")?)?,
                    strategy: str_field(j, "strategy")?.parse()?,
                    mac: parse_mac(str_field(j, "mac")?)?,
                    fdc,
                    // Missing key = pre-pipeline (combinational) request.
                    pipeline_stages: match j.get("pipeline_stages") {
                        None | Some(Json::Null) => 0,
                        Some(_) => usize_field(j, "pipeline_stages")?,
                    },
                }))
            }
            "method" => Ok(DesignRequest::Method(MethodRequest {
                method: str_field(j, "method")?.parse()?,
                n: usize_field(j, "n")?,
                signedness: match j.get("signedness") {
                    None | Some(Json::Null) => Signedness::Unsigned,
                    Some(s) => match s.as_str() {
                        Some("signed") => Signedness::Signed,
                        Some("unsigned") => Signedness::Unsigned,
                        _ => bail!("unknown signedness (valid: signed, unsigned)"),
                    },
                },
                strategy: str_field(j, "strategy")?.parse()?,
                mac: j
                    .get("mac")
                    .and_then(|b| b.as_bool())
                    .ok_or_else(|| anyhow!("mac must be a bool"))?,
                // The budget fields default when omitted (wire requests
                // rarely spell them); serialization always emits them, so
                // fingerprints are unaffected.
                budget: {
                    let d = BaselineBudget::default();
                    BaselineBudget {
                        rlmul_iters: match j.get("rlmul_iters") {
                            None | Some(Json::Null) => d.rlmul_iters,
                            Some(_) => usize_field(j, "rlmul_iters")?,
                        },
                        seed: match j.get("seed") {
                            None | Some(Json::Null) => d.seed,
                            Some(_) => u64_str_field(j, "seed")?,
                        },
                    }
                },
            })),
            "fir" | "systolic" => Ok(DesignRequest::Module(ModuleRequest {
                module: if kind == "fir" { ModuleKind::Fir } else { ModuleKind::Systolic },
                method: str_field(j, "method")?.parse()?,
                n: usize_field(j, "n")?,
                strategy: str_field(j, "strategy")?.parse()?,
                freq_hz: j
                    .get("freq_hz")
                    .and_then(|f| f.as_f64())
                    .ok_or_else(|| anyhow!("freq_hz must be a number"))?,
            })),
            other => bail!("unknown request kind '{other}'"),
        }
    }

    /// Parse from a JSON string.
    pub fn parse(text: &str) -> Result<DesignRequest> {
        let j = Json::parse(text).map_err(|e| anyhow!("request json: {e}"))?;
        DesignRequest::from_json(&j)
    }
}

/// The tier-1 design sweep: every design family × operand format the fast
/// test suite keeps green, at width `n` — the four compressor-tree
/// architectures and both accumulator modes across unsigned/signed and
/// square/rectangular formats, plus the Booth-4 generator on the square
/// formats. `ufo-mac lint` with no request iterates exactly this list (as
/// does the CI lint sweep and the clean-sweep lint test), so "tier-1 lints
/// clean" means the same thing everywhere.
pub fn tier1_requests(n: usize) -> Vec<DesignRequest> {
    let m = (n.saturating_sub(2)).max(1);
    let formats = [
        OperandFormat::unsigned(n),
        OperandFormat::signed(n),
        OperandFormat::rect(n, m),
        OperandFormat::signed_rect(n, m),
    ];
    let mut out = Vec::new();
    for fmt in formats {
        for ct in [
            CtArchitecture::UfoMac,
            CtArchitecture::Wallace,
            CtArchitecture::Dadda,
            CtArchitecture::Gomil,
        ] {
            out.push(DesignRequest::from_spec(&MultiplierSpec::new_fmt(fmt).ct(ct)));
        }
        out.push(DesignRequest::from_spec(&MultiplierSpec::new_fmt(fmt).fused_mac(true)));
        out.push(DesignRequest::from_spec(&MultiplierSpec::new_fmt(fmt).separate_mac(true)));
    }
    for fmt in [OperandFormat::unsigned(n), OperandFormat::signed(n)] {
        out.push(DesignRequest::from_spec(&MultiplierSpec::new_fmt(fmt).ppg(PpgKind::Booth4)));
    }
    // Pipelined variants: the sequential IR's tier-1 coverage — a 1-stage
    // registered multiplier plus 2-stage fused MACs in both signednesses.
    out.push(DesignRequest::from_spec(&MultiplierSpec::new(n).pipeline_stages(1)));
    for fmt in [OperandFormat::unsigned(n), OperandFormat::signed(n)] {
        out.push(DesignRequest::from_spec(
            &MultiplierSpec::new_fmt(fmt).fused_mac(true).pipeline_stages(2),
        ));
    }
    out
}

impl MulRequest {
    /// Lower back to the builder spec the synthesis pipeline consumes.
    pub fn to_spec(&self) -> MultiplierSpec {
        MultiplierSpec {
            n: self.n,
            format: self.format,
            ppg: self.ppg,
            ct: self.ct,
            order_override: self.order,
            ct_plan: self.ct_plan.clone(),
            cpa: self.cpa,
            strategy: self.strategy,
            fused_mac: self.mac == MacMode::Fused,
            separate_mac: self.mac == MacMode::Separate,
            fdc_model: self.fdc,
            pipeline_stages: self.pipeline_stages,
        }
    }
}

// -------------------------------------------------------------------
// Enum <-> string keys (stable across versions: they feed the hash).
// -------------------------------------------------------------------

fn ppg_key(p: PpgKind) -> &'static str {
    match p {
        PpgKind::AndArray => "and_array",
        PpgKind::Booth4 => "booth4",
    }
}

fn parse_ppg(s: &str) -> Result<PpgKind> {
    match s {
        "and_array" => Ok(PpgKind::AndArray),
        "booth4" => Ok(PpgKind::Booth4),
        _ => bail!("unknown ppg '{s}' (valid: and_array, booth4)"),
    }
}

fn ct_key(c: CtArchitecture) -> &'static str {
    match c {
        CtArchitecture::UfoMac => "ufo",
        CtArchitecture::UfoMacIlp => "ufo_ilp",
        CtArchitecture::Wallace => "wallace",
        CtArchitecture::Dadda => "dadda",
        CtArchitecture::Gomil => "gomil",
    }
}

fn parse_ct(s: &str) -> Result<CtArchitecture> {
    match s {
        "ufo" => Ok(CtArchitecture::UfoMac),
        "ufo_ilp" => Ok(CtArchitecture::UfoMacIlp),
        "wallace" => Ok(CtArchitecture::Wallace),
        "dadda" => Ok(CtArchitecture::Dadda),
        "gomil" => Ok(CtArchitecture::Gomil),
        _ => bail!("unknown ct '{s}' (valid: ufo, ufo_ilp, wallace, dadda, gomil)"),
    }
}

fn order_key(o: OrderStrategy) -> String {
    match o {
        OrderStrategy::Optimized => "optimized".to_string(),
        OrderStrategy::Naive => "naive".to_string(),
        OrderStrategy::Random(seed) => format!("random:{seed}"),
    }
}

fn parse_order(s: &str) -> Result<OrderStrategy> {
    if let Some(seed) = s.strip_prefix("random:") {
        return Ok(OrderStrategy::Random(seed.parse().map_err(|_| anyhow!("bad seed '{seed}'"))?));
    }
    match s {
        "optimized" => Ok(OrderStrategy::Optimized),
        "naive" => Ok(OrderStrategy::Naive),
        _ => bail!("unknown order '{s}' (valid: optimized, naive, random:<seed>)"),
    }
}

fn prefix_key(p: PrefixStructure) -> String {
    match p {
        PrefixStructure::Ripple => "ripple".to_string(),
        PrefixStructure::Sklansky => "sklansky".to_string(),
        PrefixStructure::KoggeStone => "kogge_stone".to_string(),
        PrefixStructure::BrentKung => "brent_kung".to_string(),
        PrefixStructure::HanCarlson => "han_carlson".to_string(),
        PrefixStructure::CarryIncrement(k) => format!("carry_increment:{k}"),
    }
}

fn parse_prefix(s: &str) -> Result<PrefixStructure> {
    if let Some(k) = s.strip_prefix("carry_increment:") {
        return Ok(PrefixStructure::CarryIncrement(
            k.parse().map_err(|_| anyhow!("bad block size '{k}'"))?,
        ));
    }
    match s {
        "ripple" => Ok(PrefixStructure::Ripple),
        "sklansky" => Ok(PrefixStructure::Sklansky),
        "kogge_stone" => Ok(PrefixStructure::KoggeStone),
        "brent_kung" => Ok(PrefixStructure::BrentKung),
        "han_carlson" => Ok(PrefixStructure::HanCarlson),
        _ => bail!(
            "unknown prefix structure '{s}' (valid: ripple, sklansky, kogge_stone, \
             brent_kung, han_carlson, carry_increment:<k>)"
        ),
    }
}

fn cpa_key(c: &CpaChoice) -> String {
    match c {
        CpaChoice::ProfileOptimized => "profile".to_string(),
        CpaChoice::Regular(p) => format!("regular:{}", prefix_key(*p)),
    }
}

fn parse_cpa(s: &str) -> Result<CpaChoice> {
    if s == "profile" {
        return Ok(CpaChoice::ProfileOptimized);
    }
    if let Some(p) = s.strip_prefix("regular:") {
        return Ok(CpaChoice::Regular(parse_prefix(p)?));
    }
    bail!("unknown cpa '{s}' (valid: profile, regular:<structure>)");
}

fn strategy_key(s: Strategy) -> &'static str {
    s.key()
}

fn mac_key(m: MacMode) -> &'static str {
    match m {
        MacMode::None => "none",
        MacMode::Fused => "fused",
        MacMode::Separate => "separate",
    }
}

fn parse_mac(s: &str) -> Result<MacMode> {
    match s {
        "none" => Ok(MacMode::None),
        "fused" => Ok(MacMode::Fused),
        "separate" => Ok(MacMode::Separate),
        _ => bail!("unknown mac mode '{s}' (valid: none, fused, separate)"),
    }
}

fn plan_to_json(p: &StagePlan) -> Json {
    let grid = |g: &Vec<Vec<usize>>| {
        Json::arr(
            g.iter()
                .map(|row| Json::arr(row.iter().map(|&x| Json::num(x as f64)).collect()))
                .collect(),
        )
    };
    Json::obj(vec![("f", grid(&p.f)), ("h", grid(&p.h))])
}

fn plan_from_json(j: &Json) -> Result<StagePlan> {
    let grid = |key: &str| -> Result<Vec<Vec<usize>>> {
        let rows = j
            .get(key)
            .and_then(|g| g.as_arr())
            .ok_or_else(|| anyhow!("ct_plan.{key} must be an array"))?;
        rows.iter()
            .map(|row| {
                let cells =
                    row.as_arr().ok_or_else(|| anyhow!("ct_plan.{key} rows must be arrays"))?;
                cells
                    .iter()
                    .map(|c| {
                        c.as_f64()
                            .map(|x| x as usize)
                            .ok_or_else(|| anyhow!("ct_plan entries must be numbers"))
                    })
                    .collect()
            })
            .collect()
    };
    Ok(StagePlan { f: grid("f")?, h: grid("h")? })
}

// -------------------------------------------------------------------
// JSON field helpers.
// -------------------------------------------------------------------

fn str_field<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    j.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("missing or non-string field '{key}'"))
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    let x = j
        .get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("missing or non-numeric field '{key}'"))?;
    // Reject fractional, negative, and absurd values instead of silently
    // truncating — this is the service entry point's first line of defense.
    if x.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&x) {
        bail!("field '{key}' must be a non-negative integer ≤ {}, got {x}", u32::MAX);
    }
    Ok(x as usize)
}

fn u64_str_field(j: &Json, key: &str) -> Result<u64> {
    let s = str_field(j, key)?;
    s.parse().map_err(|_| anyhow!("field '{key}' must be a decimal u64 string, got '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::spec_for;

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let a = DesignRequest::multiplier(8);
        let b = DesignRequest::multiplier(8);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Every field change moves the hash.
        let variants = [
            DesignRequest::multiplier(9),
            DesignRequest::from_spec(&MultiplierSpec::new(8).strategy(Strategy::TimingDriven)),
            DesignRequest::from_spec(&MultiplierSpec::new(8).ppg(PpgKind::Booth4)),
            DesignRequest::from_spec(&MultiplierSpec::new(8).fused_mac(true)),
            DesignRequest::from_spec(&MultiplierSpec::new(8).ct(CtArchitecture::Wallace)),
            DesignRequest::from_spec(&MultiplierSpec::new(8).order(OrderStrategy::Naive)),
            DesignRequest::from_spec(&MultiplierSpec::new(8).signed(true)),
            DesignRequest::from_spec(&MultiplierSpec::new_fmt(OperandFormat::rect(8, 7))),
            DesignRequest::from_spec(&MultiplierSpec::new(8).pipeline_stages(2)),
        ];
        for v in &variants {
            assert_ne!(a.fingerprint(), v.fingerprint(), "{v:?}");
        }
    }

    #[test]
    fn legacy_unsigned_square_requests_are_byte_stable() {
        // The operand-format subsystem must not move pre-format cache keys:
        // a default-format request serializes with NO format/signedness key
        // (so the rendered JSON — and therefore the FNV fingerprint — is
        // exactly what pre-format builds produced).
        for req in [
            DesignRequest::multiplier(8),
            DesignRequest::from_spec(&MultiplierSpec::new(16).fused_mac(true)),
            DesignRequest::method(Method::Gomil, 8, Strategy::TradeOff, false),
            DesignRequest::method(Method::RlMul, 8, Strategy::TradeOff, true),
        ] {
            let text = req.canonical().to_json_string();
            assert!(!text.contains("format"), "{text}");
            assert!(!text.contains("signedness"), "{text}");
        }
        // A combinational request renders no pipeline key either.
        let text = DesignRequest::multiplier(8).canonical().to_json_string();
        assert!(!text.contains("pipeline"), "{text}");
        // An explicit unsigned square format is the same request.
        let explicit =
            DesignRequest::from_spec(&MultiplierSpec::new(8).format(OperandFormat::unsigned(8)));
        assert_eq!(explicit.fingerprint(), DesignRequest::multiplier(8).fingerprint());
        // Parsing legacy JSON (no format key) yields the default format.
        let back = DesignRequest::parse(&DesignRequest::multiplier(8).to_json_string()).unwrap();
        match back {
            DesignRequest::Multiplier(m) => assert_eq!(m.format, OperandFormat::unsigned(8)),
            other => panic!("wrong form {other:?}"),
        }
    }

    #[test]
    fn format_roundtrips_and_splits_the_cache_key() {
        let signed = DesignRequest::from_spec(
            &MultiplierSpec::new_fmt(OperandFormat::signed_rect(4, 6)).fused_mac(true),
        );
        let text = signed.to_json_string();
        assert!(text.contains("\"format\""), "{text}");
        let back = DesignRequest::parse(&text).unwrap();
        assert_eq!(signed.fingerprint(), back.fingerprint());
        match back {
            DesignRequest::Multiplier(m) => {
                assert_eq!(m.format, OperandFormat::signed_rect(4, 6));
            }
            other => panic!("wrong form {other:?}"),
        }
        // Signed method requests round-trip and differ from unsigned.
        let sm = DesignRequest::method_with(
            Method::RlMul,
            8,
            Strategy::TradeOff,
            false,
            Signedness::Signed,
        );
        let sm_back = DesignRequest::parse(&sm.to_json_string()).unwrap();
        assert_eq!(sm.fingerprint(), sm_back.fingerprint());
        assert_ne!(
            sm.fingerprint(),
            DesignRequest::method(Method::RlMul, 8, Strategy::TradeOff, false).fingerprint()
        );
        // Deterministic signed method requests lower onto the explicit
        // signed spec (one cache entry for both spellings).
        let gm = DesignRequest::method_with(
            Method::Gomil,
            8,
            Strategy::TradeOff,
            false,
            Signedness::Signed,
        );
        let gspec = DesignRequest::from_spec(&spec_for_fmt(
            Method::Gomil,
            OperandFormat::signed(8),
            Strategy::TradeOff,
            false,
        ));
        assert_eq!(gm.fingerprint(), gspec.fingerprint());
    }

    #[test]
    fn canonical_derives_reporting_width_from_format() {
        let mut m = match DesignRequest::from_spec(&MultiplierSpec::new_fmt(
            OperandFormat::rect(4, 6),
        )) {
            DesignRequest::Multiplier(m) => m,
            other => panic!("wrong form {other:?}"),
        };
        m.n = 99; // inconsistent by hand
        let hand = DesignRequest::Multiplier(m);
        let auto = DesignRequest::from_spec(&MultiplierSpec::new_fmt(OperandFormat::rect(4, 6)));
        assert_eq!(hand.fingerprint(), auto.fingerprint());
    }

    #[test]
    fn canonical_method_equals_explicit_spec() {
        // A deterministic method request lowers to the spec it denotes.
        let via_method = DesignRequest::method(Method::Gomil, 8, Strategy::TradeOff, false);
        let via_spec =
            DesignRequest::from_spec(&spec_for(Method::Gomil, 8, Strategy::TradeOff, false));
        assert_eq!(via_method.fingerprint(), via_spec.fingerprint());
        // ...and the budget cannot split the cache for non-search methods.
        let other_budget = DesignRequest::Method(MethodRequest {
            method: Method::Gomil,
            n: 8,
            signedness: Signedness::Unsigned,
            strategy: Strategy::TradeOff,
            mac: false,
            budget: BaselineBudget { rlmul_iters: 999, seed: 1 },
        });
        assert_eq!(via_method.fingerprint(), other_budget.fingerprint());
        // ...but it does matter for RL-MUL.
        let rl_a = DesignRequest::method(Method::RlMul, 8, Strategy::TradeOff, false);
        let rl_b = DesignRequest::Method(MethodRequest {
            method: Method::RlMul,
            n: 8,
            signedness: Signedness::Unsigned,
            strategy: Strategy::TradeOff,
            mac: false,
            budget: BaselineBudget { rlmul_iters: 999, seed: 1 },
        });
        assert_ne!(rl_a.fingerprint(), rl_b.fingerprint());
    }

    #[test]
    fn canonical_zeroes_fdc_under_regular_cpa() {
        let mut spec = MultiplierSpec::new(8).cpa(CpaChoice::Regular(PrefixStructure::Sklansky));
        let a = DesignRequest::from_spec(&spec);
        spec.fdc_model = FdcModel { k: [9.0; 4], b: 4.2 };
        let b = DesignRequest::from_spec(&spec);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // With a profile-optimized CPA the model is live.
        let mut spec2 = MultiplierSpec::new(8);
        let c = DesignRequest::from_spec(&spec2);
        spec2.fdc_model = FdcModel { k: [9.0; 4], b: 4.2 };
        let d = DesignRequest::from_spec(&spec2);
        assert_ne!(c.fingerprint(), d.fingerprint());
    }

    #[test]
    fn canonical_normalizes_dead_fields() {
        // Strategy is only read by profile-optimized CPA synthesis: under a
        // regular structure it must not split the cache.
        let mk = |s: Strategy| {
            DesignRequest::from_spec(
                &MultiplierSpec::new(8)
                    .cpa(CpaChoice::Regular(PrefixStructure::Sklansky))
                    .strategy(s),
            )
        };
        assert_eq!(mk(Strategy::AreaDriven).fingerprint(), mk(Strategy::TimingDriven).fingerprint());
        // ...but it stays live under the profile-optimized CPA.
        let live = |s: Strategy| DesignRequest::from_spec(&MultiplierSpec::new(8).strategy(s));
        assert_ne!(
            live(Strategy::AreaDriven).fingerprint(),
            live(Strategy::TimingDriven).fingerprint()
        );
        // An explicit ct_plan overrides the architecture selector.
        let plan = StagePlan { f: vec![vec![0, 1]], h: vec![vec![1, 0]] };
        let with_ct = |ct: CtArchitecture| {
            DesignRequest::from_spec(&MultiplierSpec::new(4).ct(ct).with_plan(plan.clone()))
        };
        assert_eq!(
            with_ct(CtArchitecture::Wallace).fingerprint(),
            with_ct(CtArchitecture::Gomil).fingerprint()
        );
    }

    #[test]
    fn pipeline_stages_roundtrip_and_split_the_cache_key() {
        let piped = DesignRequest::from_spec(
            &MultiplierSpec::new(8).fused_mac(true).pipeline_stages(2),
        );
        let text = piped.to_json_string();
        assert!(text.contains("\"pipeline_stages\":2"), "{text}");
        let back = DesignRequest::parse(&text).unwrap();
        assert_eq!(piped.fingerprint(), back.fingerprint());
        match back {
            DesignRequest::Multiplier(m) => assert_eq!(m.pipeline_stages, 2),
            other => panic!("wrong form {other:?}"),
        }
        // Depths split the cache key; depth 0 equals the legacy request.
        let flat = DesignRequest::from_spec(&MultiplierSpec::new(8).fused_mac(true));
        assert_ne!(piped.fingerprint(), flat.fingerprint());
        let p3 =
            DesignRequest::from_spec(&MultiplierSpec::new(8).fused_mac(true).pipeline_stages(3));
        assert_ne!(piped.fingerprint(), p3.fingerprint());
        let explicit0 =
            DesignRequest::from_spec(&MultiplierSpec::new(8).fused_mac(true).pipeline_stages(0));
        assert_eq!(flat.fingerprint(), explicit0.fingerprint());
        // Legacy JSON with no key parses to depth 0.
        let legacy = DesignRequest::parse(&flat.to_json_string()).unwrap();
        match legacy {
            DesignRequest::Multiplier(m) => assert_eq!(m.pipeline_stages, 0),
            other => panic!("wrong form {other:?}"),
        }
    }

    #[test]
    fn tier1_includes_pipelined_variants() {
        let reqs = tier1_requests(8);
        let piped: Vec<_> = reqs
            .iter()
            .filter(|r| matches!(r, DesignRequest::Multiplier(m) if m.pipeline_stages > 0))
            .collect();
        assert_eq!(piped.len(), 3, "expected 3 pipelined tier-1 variants");
    }

    #[test]
    fn parse_rejects_out_of_range_numbers() {
        // Truncation at the service boundary is a silent wrong-design bug.
        let base = DesignRequest::multiplier(8).to_json_string();
        assert!(DesignRequest::parse(&base.replace("\"n\":8", "\"n\":8.9")).is_err());
        assert!(DesignRequest::parse(&base.replace("\"n\":8", "\"n\":-3")).is_err());
        assert!(DesignRequest::parse(&base.replace("\"n\":8", "\"n\":1e18")).is_err());
    }

    #[test]
    fn json_roundtrip_all_forms() {
        let reqs = vec![
            DesignRequest::multiplier(16),
            DesignRequest::from_spec(
                &MultiplierSpec::new(6)
                    .ppg(PpgKind::Booth4)
                    .ct(CtArchitecture::Dadda)
                    .cpa(CpaChoice::Regular(PrefixStructure::CarryIncrement(4)))
                    .order(OrderStrategy::Random(0xDEAD_BEEF_DEAD_BEEF))
                    .separate_mac(true),
            ),
            DesignRequest::method(Method::RlMul, 8, Strategy::TimingDriven, true),
            DesignRequest::fir(Method::UfoMac, 8, Strategy::AreaDriven, 660e6),
            DesignRequest::systolic(Method::Commercial, 8, Strategy::TradeOff, 1e9),
        ];
        for r in &reqs {
            let s = r.to_json_string();
            let back = DesignRequest::parse(&s).unwrap();
            assert_eq!(s, back.to_json_string(), "unstable round-trip for {r:?}");
            assert_eq!(r.fingerprint(), back.fingerprint());
        }
    }

    #[test]
    fn json_roundtrip_with_ct_plan() {
        let plan = StagePlan { f: vec![vec![1, 2, 0], vec![0, 1, 1]], h: vec![vec![0, 0, 1], vec![1, 0, 0]] };
        let r = DesignRequest::from_spec(&MultiplierSpec::new(4).with_plan(plan));
        let back = DesignRequest::parse(&r.to_json_string()).unwrap();
        assert_eq!(r.fingerprint(), back.fingerprint());
        match back {
            DesignRequest::Multiplier(m) => {
                let p = m.ct_plan.unwrap();
                assert_eq!(p.f, vec![vec![1, 2, 0], vec![0, 1, 1]]);
                assert_eq!(p.h, vec![vec![0, 0, 1], vec![1, 0, 0]]);
            }
            other => panic!("wrong form {other:?}"),
        }
    }

    #[test]
    fn method_budget_fields_default_when_omitted() {
        let wire = r#"{"kind":"method","method":"gomil","n":8,"strategy":"tradeoff","mac":false}"#;
        let req = DesignRequest::parse(wire).unwrap();
        match &req {
            DesignRequest::Method(m) => assert_eq!(m.budget.rlmul_iters, BaselineBudget::default().rlmul_iters),
            other => panic!("wrong form {other:?}"),
        }
        // Omitted budget == default budget, fingerprint-wise.
        assert_eq!(
            req.fingerprint(),
            DesignRequest::method(Method::Gomil, 8, Strategy::TradeOff, false).fingerprint()
        );
        // Present-but-invalid values are still hard errors.
        assert!(DesignRequest::parse(
            r#"{"kind":"method","method":"gomil","n":8,"strategy":"tradeoff","mac":false,"seed":7}"#
        )
        .is_err());
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(DesignRequest::parse("not json").is_err());
        assert!(DesignRequest::parse("{\"kind\":\"warp_drive\"}").is_err());
        assert!(DesignRequest::parse("{\"kind\":\"method\",\"method\":\"alien\"}").is_err());
    }
}
