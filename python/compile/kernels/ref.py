"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
ground truth (pytest compares kernel vs. ref on random instances, and ref
itself is validated against a python-int golden model)."""

import jax.numpy as jnp

from . import netlist_eval as ne


def netlist_eval_ref(ops, f0, f1, f2, words):
    """Reference netlist evaluation: same semantics, no pallas_call."""
    return ne._eval_body(ops, f0, f1, f2, words)


def systolic_ref(a, b, c):
    """Reference systolic MAC: exact integer GEMM + accumulate."""
    return jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32)) + c


def eval_netlist_python(ops, f0, f1, f2, words):
    """Slow python-int golden model of the netlist encoding (32-bit lanes)."""
    n = len(ops)
    batch = len(words)
    mask = 0xFFFFFFFF
    buf = [[0] * n for _ in range(batch)]
    for i in range(n):
        for lane in range(batch):
            a = buf[lane][f0[i]] if f0[i] < n else 0
            b = buf[lane][f1[i]] if f1[i] < n else 0
            c = buf[lane][f2[i]] if f2[i] < n else 0
            op = ops[i]
            if op == ne.OP_BUF:
                v = a
            elif op == ne.OP_INV:
                v = ~a
            elif op == ne.OP_AND2:
                v = a & b
            elif op == ne.OP_OR2:
                v = a | b
            elif op == ne.OP_NAND2:
                v = ~(a & b)
            elif op == ne.OP_NOR2:
                v = ~(a | b)
            elif op == ne.OP_XOR2:
                v = a ^ b
            elif op == ne.OP_XNOR2:
                v = ~(a ^ b)
            elif op == ne.OP_AOI21:
                v = ~((a & b) | c)
            elif op == ne.OP_OAI21:
                v = ~((a | b) & c)
            elif op == ne.OP_MAJ3:
                v = (a & b) | (a & c) | (b & c)
            elif op == ne.OP_CONST0:
                v = 0
            elif op == ne.OP_CONST1:
                v = mask
            elif op == ne.OP_INPUT:
                v = words[lane][min(f0[i], len(words[lane]) - 1)]
            else:
                raise ValueError(f"bad opcode {op}")
            buf[lane][i] = v & mask
    return buf
