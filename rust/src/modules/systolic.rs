//! 16×16 output-stationary systolic array (Table 2).
//!
//! Each processing element is a fused MAC (`acc ← acc + a·b`) plus operand
//! and accumulator registers; the array's achievable frequency is set by
//! the PE's combinational MAC path, and array area/power scale the PE by
//! the 256 instances plus operand-forwarding registers. The PE netlist is
//! the *real* generated MAC design — the hardware twin of the Pallas
//! `systolic` kernel the runtime executes for the end-to-end workload.

use super::{ModuleReport, DFF_AREA_UM2, DFF_ENERGY_FJ};
use crate::api::{engine, DesignRequest};
use crate::baselines::Method;
use crate::multiplier::{Design, Strategy};
use crate::sta::StaReport;
use crate::Result;

/// Array geometry (the paper's configuration).
pub const ROWS: usize = 16;
/// Array columns of the Table-2 configuration.
pub const COLS: usize = 16;

/// Report for one systolic-array configuration.
pub type SystolicReport = ModuleReport;

/// Build one PE: an `n×n` fused MAC with a `2n`-bit accumulator operand.
///
/// Shim over the unified engine; the PE is the cached fused-MAC design for
/// the method. New code should compile [`DesignRequest::systolic`].
pub fn build_pe(method: Method, n: usize, strategy: Strategy) -> Result<Design> {
    let art = engine().compile(&DesignRequest::method(method, n, strategy, true))?;
    Ok(art.design().expect("method artifact carries a design").clone())
}

/// Project a measured PE STA report onto the full array at a clock target
/// (the engine's inner path for systolic requests).
pub fn report_from_pe(rep: &StaReport, n: usize, freq_hz: f64) -> SystolicReport {
    let period_ns = 1e9 / freq_hz;
    let wns_ns = period_ns - rep.critical_delay_ns;
    let pes = (ROWS * COLS) as f64;
    // Per PE: two n-bit operand registers (a, b forwarding) + a 2n+1-bit
    // accumulator register.
    let regs_per_pe = (2 * n + 2 * n + 1) as f64;
    let area_um2 = pes * (rep.area_um2 + regs_per_pe * DFF_AREA_UM2);
    let power_mw =
        pes * (rep.power_mw + regs_per_pe * DFF_ENERGY_FJ * (freq_hz / 1e9) / 1000.0);
    SystolicReport { freq_hz, wns_ns, area_um2, power_mw }
}

/// Table-2 style report for the full array at a clock target.
///
/// Shim over the unified engine ([`DesignRequest::systolic`]); repeated
/// calls are served from the content-addressed cache.
pub fn systolic_report(
    method: Method,
    n: usize,
    strategy: Strategy,
    freq_hz: f64,
) -> Result<SystolicReport> {
    let art = engine().compile(&DesignRequest::systolic(method, n, strategy, freq_hz))?;
    Ok(art.module_report().expect("systolic artifact carries a report").clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_is_a_verified_fused_mac() {
        let pe = build_pe(Method::UfoMac, 3, Strategy::TradeOff).unwrap();
        assert!(pe.is_mac);
        let r = crate::equiv::check_multiplier(&pe).unwrap();
        assert!(r.passed && r.exhaustive);
    }

    #[test]
    fn report_scales_with_array_size() {
        let r = systolic_report(Method::UfoMac, 8, Strategy::AreaDriven, 660e6).unwrap();
        let pe = build_pe(Method::UfoMac, 8, Strategy::AreaDriven).unwrap();
        let pe_area = crate::sta::Sta::default().analyze(&pe.netlist).area_um2;
        assert!(r.area_um2 > 256.0 * pe_area, "array must include register overhead");
        assert!(r.power_mw > 0.0);
    }

    #[test]
    fn higher_clock_tightens_wns() {
        let slow = systolic_report(Method::UfoMac, 8, Strategy::TimingDriven, 660e6).unwrap();
        let fast = systolic_report(Method::UfoMac, 8, Strategy::TimingDriven, 2e9).unwrap();
        assert!(fast.wns_ns < slow.wns_ns);
    }
}
