//! Bit-parallel netlist simulation.
//!
//! Simulates a [`Netlist`] on 64 input vectors at a time by packing one
//! vector per bit lane of a `u64` word — the classic "parallel pattern"
//! simulation trick. This is the engine behind equivalence checking
//! ([`crate::equiv`]) and the toggle-based dynamic-power estimate in
//! [`crate::sta`]; the same levelized evaluation is what the Pallas
//! `netlist_eval` kernel performs on the PJRT side with u32 lanes.
//!
//! Since the netlist IR itself stores nodes as flat opcode/fanin arrays,
//! [`CompiledNetlist`] is a **zero-copy borrow** of those arrays — the
//! seed implementation paid an O(nodes) re-flattening pass (enum walk +
//! per-gate `Vec` deref) before every equivalence run; construction is now
//! free (EXPERIMENTS.md §Perf).

use crate::ir::netlist::{OP_CONST0, OP_CONST1, OP_INPUT, OP_REG};
use crate::ir::{Netlist, NodeId};

/// A netlist viewed as a flat instruction stream: one `(op, f0, f1, f2)`
/// record per node, no per-gate heap indirection. This is a zero-copy
/// borrow of the netlist's own struct-of-arrays storage (the IR and the
/// simulator share one encoding: opcodes 0–10 = `CellKind::opcode`,
/// [`OP_CONST0`], [`OP_CONST1`], [`OP_INPUT`] with the input ordinal in
/// `f0`) — the §Perf-optimized inner loop for equivalence checking and
/// toggle extraction, identical to the PJRT artifact encoding.
#[derive(Debug, Clone, Copy)]
pub struct CompiledNetlist<'a> {
    ops: &'a [u8],
    fanin: &'a [[u32; 3]],
    n_inputs: usize,
}

impl<'a> CompiledNetlist<'a> {
    /// Borrow a netlist as the simulator's flat op list. Zero-copy: the
    /// netlist already stores this encoding.
    ///
    /// Panics on a sequential netlist: this simulator is combinational
    /// (the unchecked hot loop would read a register's record as an input
    /// ordinal). Sequential netlists go through [`ClockedSim`].
    pub fn compile(nl: &'a Netlist) -> Self {
        assert!(
            !nl.is_sequential(),
            "CompiledNetlist is combinational; use sim::ClockedSim for '{}' ({} registers)",
            nl.name,
            nl.num_regs()
        );
        CompiledNetlist { ops: nl.ops(), fanin: nl.fanin_records(), n_inputs: nl.num_inputs() }
    }

    /// Number of compiled ops (== netlist nodes).
    pub fn len(&self) -> usize {
        self.ops.len()
    }
    /// Whether the program is empty.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
    /// Number of primary inputs the program samples.
    pub fn num_inputs(&self) -> usize {
        self.n_inputs
    }

    /// Evaluate into `buf` (resized as needed). `input_words[k]` feeds the
    /// k-th primary input.
    pub fn run_into(&self, buf: &mut Vec<u64>, input_words: &[u64]) {
        assert_eq!(input_words.len(), self.n_inputs, "input word count");
        if buf.len() != self.ops.len() {
            buf.resize(self.ops.len(), 0);
        }
        let b = buf.as_mut_slice();
        for i in 0..self.ops.len() {
            let [f0, f1, f2] = self.fanin[i];
            // SAFETY: the fanin records come straight from a `Netlist`
            // whose construction (`Netlist::gate`) enforces `fanin < i <
            // len`, and input ordinals are bounded by the asserted
            // `input_words` length. Dropping the bounds checks is worth
            // ~20% on the equivalence-sweep hot loop (EXPERIMENTS.md §Perf).
            let v = unsafe {
                let g = |k: u32| *b.get_unchecked(k as usize);
                match self.ops[i] {
                    0 => g(f0),
                    1 => !g(f0),
                    2 => g(f0) & g(f1),
                    3 => g(f0) | g(f1),
                    4 => !(g(f0) & g(f1)),
                    5 => !(g(f0) | g(f1)),
                    6 => g(f0) ^ g(f1),
                    7 => !(g(f0) ^ g(f1)),
                    8 => !((g(f0) & g(f1)) | g(f2)),
                    9 => !((g(f0) | g(f1)) & g(f2)),
                    10 => {
                        let (a, bb, c) = (g(f0), g(f1), g(f2));
                        (a & bb) | (a & c) | (bb & c)
                    }
                    OP_CONST0 => 0,
                    OP_CONST1 => !0,
                    _ => *input_words.get_unchecked(f0 as usize),
                }
            };
            b[i] = v;
        }
    }
}

/// Reusable simulation buffer (one word per node).
#[derive(Debug, Default)]
pub struct Simulator {
    words: Vec<u64>,
}

impl Simulator {
    /// Fresh simulator (the per-netlist "program" is the netlist's own
    /// flat storage, so there is nothing to cache beyond the word buffer).
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate the netlist on 64 packed input vectors.
    ///
    /// `input_words[k]` holds lane-packed values for the k-th primary input
    /// (in creation order). Returns the packed words of every node; index
    /// with [`NodeId::index`].
    pub fn run(&mut self, nl: &Netlist, input_words: &[u64]) -> &[u64] {
        let comp = CompiledNetlist::compile(nl);
        comp.run_into(&mut self.words, input_words);
        &self.words
    }

    /// Packed word for one node after [`Simulator::run`].
    #[inline]
    pub fn word(&self, id: NodeId) -> u64 {
        self.words[id.index()]
    }

    /// Extract the named outputs as packed words.
    pub fn output_words(&self, nl: &Netlist) -> Vec<(String, u64)> {
        nl.outputs().map(|(n, id)| (n.to_string(), self.words[id.index()])).collect()
    }
}

/// Cycle-accurate, bit-parallel simulator for **sequential** netlists —
/// the clocked counterpart of [`CompiledNetlist`].
///
/// Like the combinational simulator it evaluates 64 independent vectors at
/// once (one per bit lane of a `u64`), but register state is carried
/// across [`ClockedSim::step`] calls. Each step models one clock cycle:
///
/// 1. a full combinational sweep in which every [`crate::ir::OP_REG`] node
///    presents its *current* state `q`, then
/// 2. the synchronous update `q ← clr ? init : (en ? d : q)` per register,
///    per lane, read from the fully evaluated sweep — which is what makes
///    feedback (`d` referencing a later node) well-defined.
///
/// [`ClockedSim::reset`] models the asynchronous reset: every register
/// returns to its init value and the cycle counter restarts. Construction
/// applies it, so a fresh simulator is already in the reset state.
#[derive(Debug, Clone)]
pub struct ClockedSim<'a> {
    ops: &'a [u8],
    fanin: &'a [[u32; 3]],
    n_inputs: usize,
    /// Dense register ordinal per node (`u32::MAX` for non-registers).
    state_ix: Vec<u32>,
    /// Lane-broadcast init word per register (all-ones or all-zeros).
    init_words: Vec<u64>,
    /// Current register state, one word per register.
    state: Vec<u64>,
    /// Node values of the most recent [`ClockedSim::step`] sweep.
    words: Vec<u64>,
    /// Clock edges since the last reset.
    cycles: u64,
}

impl<'a> ClockedSim<'a> {
    /// Borrow a netlist (sequential or combinational — a register-free
    /// netlist simply has no state and `step` degenerates to one
    /// combinational sweep per call).
    pub fn new(nl: &'a Netlist) -> Self {
        let n = nl.len();
        let mut state_ix = vec![u32::MAX; n];
        let mut init_words = Vec::with_capacity(nl.num_regs());
        for i in 0..n {
            if nl.ops()[i] == OP_REG {
                state_ix[i] = init_words.len() as u32;
                let init = match nl.node(NodeId(i as u32)) {
                    crate::ir::Node::Reg { init, .. } => init,
                    _ => unreachable!("opcode says register"),
                };
                init_words.push(if init { !0u64 } else { 0 });
            }
        }
        let state = init_words.clone();
        ClockedSim {
            ops: nl.ops(),
            fanin: nl.fanin_records(),
            n_inputs: nl.num_inputs(),
            state_ix,
            init_words,
            state,
            words: vec![0u64; n],
            cycles: 0,
        }
    }

    /// Asynchronous reset: every register back to its init value, cycle
    /// counter to zero. Node words keep their last sweep (stale until the
    /// next step).
    pub fn reset(&mut self) {
        self.state.copy_from_slice(&self.init_words);
        self.cycles = 0;
    }

    /// Advance one clock cycle: evaluate the combinational sweep against
    /// `input_words` (one lane-packed word per primary input, creation
    /// order) with registers presenting their current state, then latch.
    /// Returns the node values of the sweep (the *pre-edge* view: a
    /// register's own word is the state it held during this cycle).
    pub fn step(&mut self, input_words: &[u64]) -> &[u64] {
        assert_eq!(input_words.len(), self.n_inputs, "input word count");
        let n = self.ops.len();
        for i in 0..n {
            let [f0, f1, f2] = self.fanin[i];
            let v = match self.ops[i] {
                0 => self.words[f0 as usize],
                1 => !self.words[f0 as usize],
                2 => self.words[f0 as usize] & self.words[f1 as usize],
                3 => self.words[f0 as usize] | self.words[f1 as usize],
                4 => !(self.words[f0 as usize] & self.words[f1 as usize]),
                5 => !(self.words[f0 as usize] | self.words[f1 as usize]),
                6 => self.words[f0 as usize] ^ self.words[f1 as usize],
                7 => !(self.words[f0 as usize] ^ self.words[f1 as usize]),
                8 => !((self.words[f0 as usize] & self.words[f1 as usize])
                    | self.words[f2 as usize]),
                9 => !((self.words[f0 as usize] | self.words[f1 as usize])
                    & self.words[f2 as usize]),
                10 => {
                    let (a, b, c) = (
                        self.words[f0 as usize],
                        self.words[f1 as usize],
                        self.words[f2 as usize],
                    );
                    (a & b) | (a & c) | (b & c)
                }
                OP_CONST0 => 0,
                OP_CONST1 => !0,
                OP_INPUT => input_words[f0 as usize],
                OP_REG => self.state[self.state_ix[i] as usize],
                other => panic!("unknown opcode {other} at node {i}"),
            };
            self.words[i] = v;
        }
        // Latch phase: d/en/clr are read from the completed sweep, so a
        // feedback d (later node id) sees this cycle's settled value.
        for i in 0..n {
            if self.ops[i] != OP_REG {
                continue;
            }
            let [d, en, clr] = self.fanin[i];
            let six = self.state_ix[i] as usize;
            let (dv, env, clrv) =
                (self.words[d as usize], self.words[en as usize], self.words[clr as usize]);
            let q = self.state[six];
            let iw = self.init_words[six];
            self.state[six] = (clrv & iw) | (!clrv & ((env & dv) | (!env & q)));
        }
        self.cycles += 1;
        &self.words
    }

    /// Node values of the most recent sweep (index with [`NodeId::index`]).
    #[inline]
    pub fn values(&self) -> &[u64] {
        &self.words
    }

    /// Packed word for one node after the most recent sweep.
    #[inline]
    pub fn word(&self, id: NodeId) -> u64 {
        self.words[id.index()]
    }

    /// Clock edges applied since construction or the last reset.
    #[inline]
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Number of primary inputs each step samples.
    #[inline]
    pub fn num_inputs(&self) -> usize {
        self.n_inputs
    }
}

/// Interpret a slice of output nodes as a little-endian unsigned integer for
/// one specific lane.
pub fn lane_value(words: &[u64], bits: &[NodeId], lane: u32) -> u128 {
    let mut v = 0u128;
    for (k, b) in bits.iter().enumerate() {
        v |= u128::from(words[b.index()] >> lane & 1) << k;
    }
    v
}

/// Interpret a slice of output nodes as a little-endian **two's-complement**
/// integer for one specific lane (the MSB is the sign bit) — the signed
/// counterpart of [`lane_value`] used to verify signed operand formats.
pub fn lane_value_signed(words: &[u64], bits: &[NodeId], lane: u32) -> i128 {
    crate::util::sign_extend(lane_value(words, bits, lane), bits.len())
}

/// Pack per-lane bit values into input words: `assignments[lane][input]`.
pub fn pack_lanes(assignments: &[Vec<bool>]) -> Vec<u64> {
    assert!(!assignments.is_empty() && assignments.len() <= 64);
    let n_inputs = assignments[0].len();
    let mut words = vec![0u64; n_inputs];
    for (lane, assign) in assignments.iter().enumerate() {
        assert_eq!(assign.len(), n_inputs);
        for (i, bit) in assign.iter().enumerate() {
            if *bit {
                words[i] |= 1u64 << lane;
            }
        }
    }
    words
}

/// Count output toggles between consecutive random vectors for every node —
/// the activity factor feeding the dynamic-power report.
///
/// Combinational netlists run `rounds`×64 random vectors (xorshift-seeded,
/// deterministic) through the compiled evaluator; netlists with registers
/// are routed through [`clocked_toggle_activity`] instead — `rounds`
/// clocked cycles of fresh random stimulus from the same seed, so measured
/// activity is cycle-accurate (registers toggle on actual state
/// transitions, not on a combinational re-evaluation that ignores state).
/// Returns per-node toggle probability in [0,1]. All buffers (current and
/// previous node words, input words) are allocated once and reused across
/// rounds — the seed implementation cloned the first round's buffer and
/// allocated a fresh input-word `Vec` per round (EXPERIMENTS.md §Perf).
pub fn toggle_activity(nl: &Netlist, rounds: usize, seed: u64) -> Vec<f64> {
    if nl.is_sequential() {
        return clocked_toggle_activity(nl, rounds, seed);
    }
    let comp = CompiledNetlist::compile(nl);
    let mut state = seed | 1;
    let mut rng = move || {
        // xorshift64* — deterministic, dependency-free
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        state.wrapping_mul(0x2545_F491_4F6C_DD1D)
    };
    let n_in = nl.num_inputs();
    let mut toggles = vec![0u64; nl.len()];
    let mut total_pairs = 0u64;
    let mut cur: Vec<u64> = Vec::new();
    let mut prev: Vec<u64> = Vec::new();
    let mut words = vec![0u64; n_in];
    for round in 0..rounds {
        for w in words.iter_mut() {
            *w = rng();
        }
        comp.run_into(&mut cur, &words);
        if round > 0 {
            for i in 0..cur.len() {
                toggles[i] += (cur[i] ^ prev[i]).count_ones() as u64;
            }
            total_pairs += 64;
        }
        std::mem::swap(&mut cur, &mut prev);
    }
    toggles
        .iter()
        .map(|&t| if total_pairs == 0 { 0.0 } else { t as f64 / total_pairs as f64 })
        .collect()
}

/// Cycle-accurate toggle counting for sequential netlists: drive a
/// [`ClockedSim`] from reset for `rounds` cycles of fresh 64-lane random
/// stimulus (same xorshift discipline and seed interpretation as the
/// combinational path) and count per-node toggles between consecutive
/// pre-edge value views. Register nodes therefore toggle exactly when
/// their latched state changes between cycles.
pub fn clocked_toggle_activity(nl: &Netlist, rounds: usize, seed: u64) -> Vec<f64> {
    let mut sim = ClockedSim::new(nl);
    let mut state = seed | 1;
    let mut rng = move || {
        state ^= state >> 12;
        state ^= state << 25;
        state ^= state >> 27;
        state.wrapping_mul(0x2545_F491_4F6C_DD1D)
    };
    let mut toggles = vec![0u64; nl.len()];
    let mut total_pairs = 0u64;
    let mut prev: Vec<u64> = Vec::new();
    let mut words = vec![0u64; sim.num_inputs()];
    for cycle in 0..rounds {
        for w in words.iter_mut() {
            *w = rng();
        }
        let cur = sim.step(&words);
        if cycle > 0 {
            for (i, &c) in cur.iter().enumerate() {
                toggles[i] += (c ^ prev[i]).count_ones() as u64;
            }
            total_pairs += 64;
        }
        prev.clear();
        prev.extend_from_slice(cur);
    }
    toggles
        .iter()
        .map(|&t| if total_pairs == 0 { 0.0 } else { t as f64 / total_pairs as f64 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Netlist;

    /// 2-bit ripple adder built from discrete gates.
    fn adder2() -> (Netlist, Vec<NodeId>) {
        let mut nl = Netlist::new("add2");
        let a: Vec<_> = (0..2).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<_> = (0..2).map(|i| nl.input(format!("b{i}"))).collect();
        // bit 0: half adder
        let s0 = nl.xor2(a[0], b[0]);
        let c0 = nl.and2(a[0], b[0]);
        // bit 1: full adder
        let x1 = nl.xor2(a[1], b[1]);
        let s1 = nl.xor2(x1, c0);
        let g1 = nl.and2(a[1], b[1]);
        let p1 = nl.and2(x1, c0);
        let c1 = nl.or2(g1, p1);
        nl.output("s0", s0);
        nl.output("s1", s1);
        nl.output("c", c1);
        (nl, vec![s0, s1, c1])
    }

    #[test]
    fn adder2_exhaustive() {
        let (nl, bits) = adder2();
        // all 16 combinations fit in 16 lanes
        let assigns: Vec<Vec<bool>> = (0..16u32)
            .map(|v| vec![v & 1 != 0, v >> 1 & 1 != 0, v >> 2 & 1 != 0, v >> 3 & 1 != 0])
            .collect();
        let words = pack_lanes(&assigns);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        for v in 0..16u32 {
            let a = v & 3;
            let b = v >> 2 & 3;
            let got = lane_value(&vals, &bits, v);
            assert_eq!(got, u128::from(a + b), "a={a} b={b}");
        }
    }

    #[test]
    fn lane_value_signed_reads_twos_complement() {
        let (nl, bits) = adder2();
        // a = 3, b = 2 → s = 5 = 0b101 → signed over 3 bits = -3.
        let words = pack_lanes(&[vec![true, true, false, true]]);
        let mut sim = Simulator::new();
        let vals = sim.run(&nl, &words).to_vec();
        assert_eq!(lane_value(&vals, &bits, 0), 5);
        assert_eq!(lane_value_signed(&vals, &bits, 0), -3);
        assert_eq!(lane_value_signed(&vals, &bits[..2], 0), 1); // 0b01
        assert_eq!(lane_value_signed(&vals, &[], 0), 0);
    }

    #[test]
    fn constants_evaluate() {
        let mut nl = Netlist::new("c");
        let one = nl.constant(true);
        let zero = nl.constant(false);
        let o = nl.and2(one, zero);
        let o2 = nl.or2(one, zero);
        nl.output("and", o);
        nl.output("or", o2);
        let mut sim = Simulator::new();
        sim.run(&nl, &[]);
        assert_eq!(sim.word(o), 0);
        assert_eq!(sim.word(o2), !0);
    }

    #[test]
    fn compiled_is_zero_copy_of_the_netlist() {
        let (nl, _) = adder2();
        let comp = CompiledNetlist::compile(&nl);
        assert_eq!(comp.len(), nl.len());
        assert_eq!(comp.num_inputs(), nl.num_inputs());
        assert!(std::ptr::eq(comp.ops.as_ptr(), nl.ops().as_ptr()));
        assert!(std::ptr::eq(comp.fanin.as_ptr(), nl.fanin_records().as_ptr()));
    }

    /// Toggle flip-flop: q feeds back through an inverter into its own d.
    /// Built with the sanctioned feedback recipe (`reg_raw` seed +
    /// `set_reg_data` patch).
    fn toggle_ff() -> (Netlist, NodeId, NodeId, NodeId) {
        let mut nl = Netlist::new("tff");
        let en = nl.input("en");
        let clr = nl.input("clr");
        let q = nl.reg_raw(0, en.0, clr.0, false);
        let nq = nl.inv(q);
        nl.set_reg_data(q, nq);
        nl.output("q", q);
        nl.validate().unwrap();
        (nl, q, en, clr)
    }

    #[test]
    fn clocked_toggle_ff_counts_edges() {
        let (nl, q, _, _) = toggle_ff();
        let mut sim = ClockedSim::new(&nl);
        // en=1, clr=0 on every lane: q alternates 0,1,0,1,... Each step
        // returns the *pre-edge* view, so sweep k shows the state after
        // k-1 edges: (k-1) mod 2.
        for sweep in 1..=6u64 {
            let view = sim.step(&[!0, 0]);
            let expect = if (sweep - 1) % 2 == 0 { 0u64 } else { !0 };
            assert_eq!(view[q.index()], expect, "sweep {sweep}");
            assert_eq!(sim.cycles(), sweep);
        }
    }

    #[test]
    fn clocked_en_stalls_and_clr_clears() {
        let (nl, q, _, _) = toggle_ff();
        let mut sim = ClockedSim::new(&nl);
        sim.step(&[!0, 0]); // edge 1: q becomes 1
        sim.step(&[0, 0]); // en=0: hold
        sim.step(&[0, 0]); // still holding
        let view = sim.step(&[0, 0]);
        assert_eq!(view[q.index()], !0, "held the toggled value across stalls");
        // clr wins over en: q returns to init (0) even with en=1.
        sim.step(&[!0, !0]);
        let view = sim.step(&[0, 0]);
        assert_eq!(view[q.index()], 0, "clr returns to init");
    }

    #[test]
    fn clocked_reset_restores_init_state() {
        let (nl, q, _, _) = toggle_ff();
        let mut sim = ClockedSim::new(&nl);
        sim.step(&[!0, 0]);
        sim.step(&[0, 0]);
        assert_eq!(sim.word(q), !0);
        sim.reset();
        assert_eq!(sim.cycles(), 0);
        let view = sim.step(&[0, 0]);
        assert_eq!(view[q.index()], 0, "init state after reset");
    }

    #[test]
    fn clocked_two_rank_pipeline_has_two_cycle_latency() {
        // x → reg → reg: the input value appears at the second rank's
        // output exactly two edges later.
        let mut nl = Netlist::new("pipe2");
        let x = nl.input("x");
        let en = nl.constant(true);
        let clr = nl.constant(false);
        let r1 = nl.reg(x, en, clr, false);
        let r2 = nl.reg(r1, en, clr, false);
        nl.output("y", r2);
        let mut sim = ClockedSim::new(&nl);
        let pattern = 0xDEAD_BEEF_0BAD_F00Du64;
        sim.step(&[pattern]); // edge 1: r1 captures pattern
        sim.step(&[0]); // edge 2: r2 captures pattern
        let view = sim.step(&[0]); // sweep 3 shows r2 = pattern
        assert_eq!(view[r2.index()], pattern);
        assert_eq!(view[r1.index()], 0, "rank 1 moved on");
    }

    #[test]
    fn clocked_matches_combinational_on_register_free_netlists() {
        let (nl, bits) = adder2();
        let assigns: Vec<Vec<bool>> = (0..16u32)
            .map(|v| vec![v & 1 != 0, v >> 1 & 1 != 0, v >> 2 & 1 != 0, v >> 3 & 1 != 0])
            .collect();
        let words = pack_lanes(&assigns);
        let mut clocked = ClockedSim::new(&nl);
        let cw = clocked.step(&words).to_vec();
        let mut sim = Simulator::new();
        let sw = sim.run(&nl, &words).to_vec();
        assert_eq!(cw, sw);
        let _ = bits;
    }

    #[test]
    #[should_panic(expected = "combinational")]
    fn combinational_compile_rejects_sequential() {
        let (nl, _, _, _) = toggle_ff();
        let _ = CompiledNetlist::compile(&nl);
    }

    #[test]
    fn toggle_activity_sane() {
        let (nl, _) = adder2();
        let act = toggle_activity(&nl, 32, 42);
        // inputs are random ⇒ toggle prob near 0.5; all activities in [0,1]
        for (i, a) in act.iter().enumerate() {
            assert!((0.0..=1.0).contains(a), "node {i} activity {a}");
        }
        let inputs = nl.inputs();
        for id in inputs {
            assert!((act[id.index()] - 0.5).abs() < 0.1);
        }
    }
}
