"""Systolic MAC kernel vs. reference (exact integer GEMM)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import systolic as sy


@pytest.mark.parametrize("dtype,lo,hi", [(np.int32, -128, 127), (np.int32, -32768, 32767)])
def test_kernel_matches_ref(dtype, lo, hi):
    rng = np.random.default_rng(0)
    a = rng.integers(lo, hi + 1, size=(sy.PES, sy.K_STEPS)).astype(dtype)
    b = rng.integers(lo, hi + 1, size=(sy.K_STEPS, sy.PES)).astype(dtype)
    c = rng.integers(-(2**20), 2**20, size=(sy.PES, sy.PES)).astype(np.int32)
    out = np.asarray(sy.systolic_mac(a, b, c))
    want = np.asarray(ref.systolic_ref(a, b, c))
    np.testing.assert_array_equal(out, want)


def test_matches_python_integer_gemm():
    rng = np.random.default_rng(7)
    a = rng.integers(-128, 128, size=(sy.PES, sy.K_STEPS)).astype(np.int32)
    b = rng.integers(-128, 128, size=(sy.K_STEPS, sy.PES)).astype(np.int32)
    c = np.zeros((sy.PES, sy.PES), dtype=np.int32)
    out = np.asarray(sy.systolic_mac(a, b, c))
    want = a.astype(np.int64) @ b.astype(np.int64)
    np.testing.assert_array_equal(out.astype(np.int64), want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_accumulation_chains(seed):
    """Chained executions = one big GEMM (the runtime's streaming mode)."""
    rng = np.random.default_rng(seed)
    a1 = rng.integers(-128, 128, size=(sy.PES, sy.K_STEPS)).astype(np.int32)
    b1 = rng.integers(-128, 128, size=(sy.K_STEPS, sy.PES)).astype(np.int32)
    a2 = rng.integers(-128, 128, size=(sy.PES, sy.K_STEPS)).astype(np.int32)
    b2 = rng.integers(-128, 128, size=(sy.K_STEPS, sy.PES)).astype(np.int32)
    c0 = np.zeros((sy.PES, sy.PES), dtype=np.int32)
    c1 = np.asarray(sy.systolic_mac(a1, b1, c0))
    c2 = np.asarray(sy.systolic_mac(a2, b2, c1))
    big_a = np.concatenate([a1, a2], axis=1).astype(np.int64)
    big_b = np.concatenate([b1, b2], axis=0).astype(np.int64)
    np.testing.assert_array_equal(c2.astype(np.int64), big_a @ big_b)


def test_saturating_free_exactness_at_extremes():
    """All-extreme operands stay exact in int32 (no silent overflow at
    this K: 64 × 128 × 128 ≈ 2^20 ≪ 2^31)."""
    a = np.full((sy.PES, sy.K_STEPS), -128, dtype=np.int8)
    b = np.full((sy.K_STEPS, sy.PES), 127, dtype=np.int8)
    c = np.zeros((sy.PES, sy.PES), dtype=np.int32)
    out = np.asarray(sy.systolic_mac(a, b, c))
    assert (out == -128 * 127 * sy.K_STEPS).all()
