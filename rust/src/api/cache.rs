//! Sharded, content-addressed, in-memory design cache.
//!
//! Keys are request [`Fingerprint`]s (content hashes of canonical request
//! forms); values are immutable [`DesignArtifact`]s behind `Arc`, so a hit
//! is one shard-lock acquisition plus a refcount bump — no netlist is ever
//! copied. Sharding keeps the batch compiler's worker threads from
//! serializing on one mutex; statistics are lock-free atomics.

use super::engine::DesignArtifact;
use super::request::Fingerprint;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Aggregate cache counters (monotone over the cache's lifetime).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that required a fresh synthesis.
    pub misses: u64,
    /// Artifacts currently cached.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` (0 when the cache was never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Fingerprint → `Arc<DesignArtifact>` map, split over `shards` mutexes.
pub struct DesignCache {
    shards: Vec<Mutex<HashMap<u128, Arc<DesignArtifact>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl DesignCache {
    /// Empty cache split over `shards` mutexes (min 1).
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        DesignCache {
            shards: (0..shards).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn shard(&self, fp: Fingerprint) -> &Mutex<HashMap<u128, Arc<DesignArtifact>>> {
        &self.shards[fp.shard(self.shards.len())]
    }

    /// Look up a fingerprint, recording a hit or miss.
    pub fn get(&self, fp: Fingerprint) -> Option<Arc<DesignArtifact>> {
        let found = self.shard(fp).lock().unwrap().get(&fp.0).cloned();
        match &found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Insert an artifact, returning the canonical `Arc` for the key.
    ///
    /// If two workers compiled the same request concurrently, the first
    /// insert wins and both callers get the same pointer — the engine's
    /// "identical request ⇒ identical artifact" guarantee.
    pub fn insert(&self, fp: Fingerprint, artifact: DesignArtifact) -> Arc<DesignArtifact> {
        let mut shard = self.shard(fp).lock().unwrap();
        shard.entry(fp.0).or_insert_with(|| Arc::new(artifact)).clone()
    }

    /// Number of cached artifacts.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Whether the cache currently holds no artifacts.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry (counters are preserved).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }

    /// Aggregate hit/miss/entry counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(x: u128) -> Fingerprint {
        Fingerprint(x)
    }

    fn dummy() -> DesignArtifact {
        // A tiny real artifact via the engine keeps this test honest but
        // slow; a unit-cache test only needs *an* artifact, so build the
        // smallest design directly.
        let eng = crate::api::SynthEngine::new(crate::api::EngineConfig::default());
        let art = eng.compile(&crate::api::DesignRequest::multiplier(2)).unwrap();
        (*art).clone()
    }

    #[test]
    fn hit_miss_accounting_and_identity() {
        let cache = DesignCache::new(4);
        assert!(cache.get(fp(1)).is_none());
        let a = cache.insert(fp(1), dummy());
        let b = cache.get(fp(1)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn first_insert_wins() {
        let cache = DesignCache::new(2);
        let a = cache.insert(fp(7), dummy());
        let b = cache.insert(fp(7), dummy());
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }
}
