//! End-to-end multiplier and fused-MAC assembly (PPG → CT → CPA).
//!
//! [`MultiplierSpec`] is the public entry point: pick a bit width, a CT
//! architecture, a CPA choice and a strategy, call [`MultiplierSpec::build`]
//! and get a [`Design`] — a self-contained gate netlist with named operand
//! inputs and product outputs, plus the structural metadata the benchmarks
//! report. The fused-MAC path (§2.3) injects the accumulator rows into the
//! CT; the non-fused variant (conventional MAC: multiply, then add) exists
//! as the ablation the paper's Figure-12 discussion implies.

use crate::cpa::{self, CpaColumn, CpaStrategy, FdcModel, PrefixStructure};
use crate::ct::{self, CtArchitecture, OrderStrategy, StagePlan};
use crate::ir::{CellLib, Netlist, NodeId};
use crate::ppg::{self, PpgKind};
use crate::sta::TimingStats;
use crate::synth::{CompressorTiming, Sig};
use crate::Result;
use anyhow::bail;

/// Which CPA the design uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CpaChoice {
    /// UFO-MAC §4: hybrid initial structure from the CT profile +
    /// Algorithm-2 timing-driven optimization.
    ProfileOptimized,
    /// A fixed regular prefix structure (baselines).
    Regular(PrefixStructure),
}

/// Overall design strategy (maps to the paper's three synthesis presets).
pub type Strategy = CpaStrategy;

/// Specification for a multiplier / MAC design.
#[derive(Debug, Clone)]
pub struct MultiplierSpec {
    /// Operand bit width.
    pub n: usize,
    /// Partial-product generator.
    pub ppg: PpgKind,
    /// Compressor-tree architecture.
    pub ct: CtArchitecture,
    /// Interconnect-order override.
    pub order_override: Option<OrderStrategy>,
    /// Custom stage plan (used by the RL-MUL baseline's searched trees).
    pub ct_plan: Option<StagePlan>,
    /// Carry-propagate adder choice.
    pub cpa: CpaChoice,
    /// Synthesis strategy preset.
    pub strategy: Strategy,
    /// Fuse a `2n`-bit accumulator into the CT (§2.3).
    pub fused_mac: bool,
    /// Conventional MAC: multiply then add with a separate CPA.
    pub separate_mac: bool,
    /// FDC timing model driving CPA optimization.
    pub fdc_model: FdcModel,
}

impl MultiplierSpec {
    /// UFO-MAC defaults for an `n×n` multiplier.
    pub fn new(n: usize) -> Self {
        MultiplierSpec {
            n,
            ppg: PpgKind::AndArray,
            ct: CtArchitecture::UfoMac,
            order_override: None,
            ct_plan: None,
            cpa: CpaChoice::ProfileOptimized,
            strategy: CpaStrategy::TradeOff,
            fused_mac: false,
            separate_mac: false,
            fdc_model: FdcModel::default_prior(),
        }
    }

    /// Set the synthesis strategy preset.
    pub fn strategy(mut self, s: Strategy) -> Self {
        self.strategy = s;
        self
    }
    /// Set the compressor-tree architecture.
    pub fn ct(mut self, ct: CtArchitecture) -> Self {
        self.ct = ct;
        self
    }
    /// Set the CPA choice.
    pub fn cpa(mut self, cpa: CpaChoice) -> Self {
        self.cpa = cpa;
        self
    }
    /// Set the partial-product generator.
    pub fn ppg(mut self, ppg: PpgKind) -> Self {
        self.ppg = ppg;
        self
    }
    /// Toggle the §2.3 fused accumulator.
    pub fn fused_mac(mut self, yes: bool) -> Self {
        self.fused_mac = yes;
        self
    }
    /// Toggle the conventional multiply-then-add MAC.
    pub fn separate_mac(mut self, yes: bool) -> Self {
        self.separate_mac = yes;
        self
    }
    /// Force an interconnect-order strategy.
    pub fn order(mut self, o: OrderStrategy) -> Self {
        self.order_override = Some(o);
        self
    }
    /// Use a custom CT stage plan (RL-MUL searched trees).
    pub fn with_plan(mut self, plan: StagePlan) -> Self {
        self.ct_plan = Some(plan);
        self
    }
    /// Use a fitted FDC timing model.
    pub fn fdc(mut self, m: FdcModel) -> Self {
        self.fdc_model = m;
        self
    }

    /// Build the gate-level design.
    ///
    /// Shim over the unified engine: the spec is captured as a
    /// [`crate::api::DesignRequest`] and compiled by the process-global
    /// [`crate::api::SynthEngine`], so repeated identical builds are
    /// served from the content-addressed design cache. New code should
    /// compile requests directly.
    pub fn build(&self) -> Result<Design> {
        // Validate the one state a DesignRequest cannot represent.
        if self.fused_mac && self.separate_mac {
            bail!("fused_mac and separate_mac are mutually exclusive");
        }
        let art = crate::api::engine().compile(&crate::api::DesignRequest::from_spec(self))?;
        Ok(art.design().expect("multiplier artifact carries a design").clone())
    }

    /// Build against a caller-provided cell library and timing model —
    /// the engine's uncached inner path. Prefer [`MultiplierSpec::build`]
    /// (cached) unless you are the engine.
    pub fn build_with(&self, lib: &CellLib, tm: &CompressorTiming) -> Result<Design> {
        if self.n < 2 {
            bail!("multiplier width must be ≥ 2");
        }
        if self.fused_mac && self.separate_mac {
            bail!("fused_mac and separate_mac are mutually exclusive");
        }
        let n = self.n;
        let mut nl = Netlist::new(format!(
            "{}{}x{}",
            if self.fused_mac || self.separate_mac { "mac" } else { "mul" },
            n,
            n
        ));
        let a: Vec<NodeId> = (0..n).map(|i| nl.input(format!("a{i}"))).collect();
        let b: Vec<NodeId> = (0..n).map(|i| nl.input(format!("b{i}"))).collect();
        let c: Vec<NodeId> = if self.fused_mac || self.separate_mac {
            (0..2 * n).map(|i| nl.input(format!("c{i}"))).collect()
        } else {
            vec![]
        };

        // PPG. Fused MACs produce a 2n+1-bit result, so a Booth matrix
        // must stay exact one column further (its compaction is modular).
        let mut matrix = if self.ppg == PpgKind::Booth4 && self.fused_mac {
            ppg::booth4_wide(&mut nl, lib, &a, &b, 2 * n + 1)
        } else {
            ppg::generate(&mut nl, lib, self.ppg, &a, &b)
        };
        if self.fused_mac {
            let addend: Vec<Sig> = c.iter().map(|&id| Sig::new(id, 0.0)).collect();
            matrix.add_addend(&addend);
        }

        // CT.
        let ct_out = match &self.ct_plan {
            Some(plan) => {
                let mut cols = matrix.columns;
                cols.resize(plan.width().max(cols.len()), Vec::new());
                ct::build_ct(
                    &mut nl,
                    tm,
                    cols,
                    plan,
                    self.order_override.unwrap_or(OrderStrategy::Naive),
                )
            }
            None => ct::synthesize(&mut nl, tm, matrix.columns, self.ct, self.order_override),
        };

        // CPA over the two compressed rows.
        let width = ct_out.rows.len();
        let cpa_cols: Vec<CpaColumn> = (0..width)
            .map(|j| {
                let col = &ct_out.rows[j];
                match col.len() {
                    0 => {
                        let z = nl.constant(false);
                        CpaColumn { a: Sig::new(z, 0.0), b: None }
                    }
                    1 => CpaColumn { a: col[0], b: None },
                    _ => CpaColumn { a: col[0], b: Some(col[1]) },
                }
            })
            .collect();
        let (graph, cpa_timing) = match self.cpa {
            CpaChoice::ProfileOptimized => {
                let (g, rep) =
                    cpa::synthesize_for_profile(&ct_out.profile, self.strategy, &self.fdc_model);
                (g, rep.timing)
            }
            CpaChoice::Regular(s) => (cpa::build(s, width), TimingStats::default()),
        };
        let cpa_out = cpa::expand(&mut nl, &graph, &cpa_cols);

        // Product bits: 2n for a multiplier, 2n+1 for a fused MAC.
        let want = if self.fused_mac || self.separate_mac { 2 * n + 1 } else { 2 * n };
        let mut product: Vec<NodeId> = cpa_out.sum;
        // The CPA yields width+1 bits; pad (never expected) or trim to want.
        while product.len() < want {
            let z = nl.constant(false);
            product.push(z);
        }
        product.truncate(want);

        // Conventional MAC: a second, separate CPA adds the accumulator.
        if self.separate_mac {
            let add_w = 2 * n;
            let cols2: Vec<CpaColumn> = (0..add_w)
                .map(|j| CpaColumn {
                    a: Sig::new(product[j], 0.0),
                    b: Some(Sig::new(c[j], 0.0)),
                })
                .collect();
            let g2 = match self.cpa {
                CpaChoice::Regular(s) => cpa::build(s, add_w),
                CpaChoice::ProfileOptimized => {
                    // No CT profile here: uniform arrival, Sklansky-style.
                    cpa::build(PrefixStructure::Sklansky, add_w)
                }
            };
            let out2 = cpa::expand(&mut nl, &g2, &cols2);
            product = out2.sum;
            product.truncate(2 * n + 1);
        }

        for (i, &p) in product.iter().enumerate() {
            nl.output(format!("p{i}"), p);
        }
        nl.validate().map_err(|e| anyhow::anyhow!("netlist invalid: {e}"))?;
        Ok(Design {
            n,
            is_mac: self.fused_mac || self.separate_mac,
            netlist: nl,
            a,
            b,
            c,
            product,
            ct_stages: ct_out.stages,
            profile: ct_out.profile,
            cpa_nodes: graph.size(),
            timing: cpa_timing,
        })
    }
}

/// A built design: netlist + interface + structural metadata.
#[derive(Debug, Clone)]
pub struct Design {
    /// Operand bit width.
    pub n: usize,
    /// Whether the design accumulates (`a·b + c`).
    pub is_mac: bool,
    /// The gate-level netlist.
    pub netlist: Netlist,
    /// Operand `a` input bits, LSB first.
    pub a: Vec<NodeId>,
    /// Operand `b` input bits, LSB first.
    pub b: Vec<NodeId>,
    /// Accumulator input bits (empty for plain multipliers).
    pub c: Vec<NodeId>,
    /// Product output bits, LSB first.
    pub product: Vec<NodeId>,
    /// Compressor-tree stage count realized.
    pub ct_stages: usize,
    /// CT output arrival-estimate profile (ns) per column.
    pub profile: Vec<f64>,
    /// CPA prefix-node count (area proxy).
    pub cpa_nodes: usize,
    /// Timing-evaluation work the CPA optimization performed while
    /// building this design (incremental vs full, see [`TimingStats`]).
    pub timing: TimingStats,
}

impl Design {
    /// Golden reference: what the hardware must compute.
    pub fn golden(&self, a: u128, b: u128, c: u128) -> u128 {
        let mask = (1u128 << self.product.len()) - 1;
        (a * b + if self.is_mac { c } else { 0 }) & mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{lane_value, pack_lanes, Simulator};

    fn exhaustive(spec: &MultiplierSpec) {
        let d = spec.build().unwrap();
        let n = d.n;
        let mut sim = Simulator::new();
        let na = 1u32 << n;
        let all: Vec<(u32, u32, u32)> = (0..na)
            .flat_map(|x| (0..na).map(move |y| (x, y, (x.wrapping_mul(13) ^ y) & (1 << (2 * n)) - 1)))
            .collect();
        for chunk in all.chunks(64) {
            let assigns: Vec<Vec<bool>> = chunk
                .iter()
                .map(|(x, y, z)| {
                    let mut v: Vec<bool> = (0..n).map(|k| x >> k & 1 != 0).collect();
                    v.extend((0..n).map(|k| y >> k & 1 != 0));
                    if d.is_mac {
                        v.extend((0..2 * n).map(|k| z >> k & 1 != 0));
                    }
                    v
                })
                .collect();
            let words = pack_lanes(&assigns);
            let vals = sim.run(&d.netlist, &words).to_vec();
            for (lane, (x, y, z)) in chunk.iter().enumerate() {
                let got = lane_value(&vals, &d.product, lane as u32);
                let want = d.golden(u128::from(*x), u128::from(*y), u128::from(*z));
                assert_eq!(got, want, "a={x} b={y} c={z}");
            }
        }
    }

    #[test]
    fn ufo_multiplier_4x4_exhaustive() {
        exhaustive(&MultiplierSpec::new(4));
    }

    #[test]
    fn ufo_multiplier_strategies_4x4() {
        for s in [CpaStrategy::AreaDriven, CpaStrategy::TimingDriven] {
            exhaustive(&MultiplierSpec::new(4).strategy(s));
        }
    }

    #[test]
    fn baseline_cts_4x4() {
        for ct in [CtArchitecture::Wallace, CtArchitecture::Dadda, CtArchitecture::Gomil] {
            exhaustive(
                &MultiplierSpec::new(4)
                    .ct(ct)
                    .cpa(CpaChoice::Regular(PrefixStructure::KoggeStone)),
            );
        }
    }

    #[test]
    fn booth_multiplier_4x4() {
        exhaustive(&MultiplierSpec::new(4).ppg(PpgKind::Booth4));
    }

    #[test]
    fn fused_mac_3x3_exhaustive() {
        exhaustive(&MultiplierSpec::new(3).fused_mac(true));
    }

    #[test]
    fn separate_mac_3x3_exhaustive() {
        exhaustive(
            &MultiplierSpec::new(3)
                .separate_mac(true)
                .cpa(CpaChoice::Regular(PrefixStructure::Sklansky)),
        );
    }

    #[test]
    fn fused_mac_beats_separate_mac() {
        // §2.3: fusing the accumulator into the CT eliminates a whole CPA
        // stage. With an identical CPA structure on both variants, the
        // fused design must be strictly faster and no more than marginally
        // larger (it trades a full prefix network for ~2n compressors).
        let sta = crate::sta::Sta::default();
        let fused = MultiplierSpec::new(8)
            .fused_mac(true)
            .cpa(CpaChoice::Regular(PrefixStructure::Sklansky))
            .build()
            .unwrap();
        let sep = MultiplierSpec::new(8)
            .separate_mac(true)
            .cpa(CpaChoice::Regular(PrefixStructure::Sklansky))
            .build()
            .unwrap();
        let rf = sta.analyze(&fused.netlist);
        let rs = sta.analyze(&sep.netlist);
        assert!(
            rf.critical_delay_ns < rs.critical_delay_ns,
            "delay {} vs {}",
            rf.critical_delay_ns,
            rs.critical_delay_ns
        );
        assert!(rf.area_um2 < rs.area_um2 * 1.05, "area {} vs {}", rf.area_um2, rs.area_um2);
    }

    #[test]
    fn profile_is_trapezoidal_for_16bit() {
        // Figure 1: middle columns arrive last.
        let d = MultiplierSpec::new(16).build().unwrap();
        let w = d.profile.len();
        let mid = d.profile[w / 2];
        assert!(mid >= d.profile[1], "mid {} vs lsb {}", mid, d.profile[1]);
        assert!(mid >= d.profile[w - 1], "mid {} vs msb {}", mid, d.profile[w - 1]);
        assert!(mid > 0.0);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(MultiplierSpec::new(1).build().is_err());
        assert!(MultiplierSpec::new(4).fused_mac(true).separate_mac(true).build().is_err());
    }
}
