//! Offline-build utilities: deterministic RNG, a minimal JSON value with
//! writer *and* parser, and a tiny CLI argument helper.
//!
//! The build environment vendors only the `xla` dependency closure, so the
//! usual ecosystem crates (`rand`, `serde_json`, `clap`) are implemented
//! here at the scale this project needs. The parser exists for the `api`
//! layer's [`crate::api::DesignRequest`] round-trip; reports are still
//! write-only.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// SplitMix64 + xoshiro256** — deterministic, seedable, dependency-free RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministic generator from a 64-bit seed.
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion, as recommended by the xoshiro authors.
        let mut sm = seed;
        let mut next = move || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free approximation is fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Two's-complement value of the low `bits` bits of `x` — the one
/// sign-extension helper shared by the signed reference models
/// ([`crate::multiplier::Design::expected`]) and the signed lane reader
/// ([`crate::sim::lane_value_signed`]).
pub fn sign_extend(x: u128, bits: usize) -> i128 {
    if bits == 0 {
        return 0;
    }
    debug_assert!(bits <= 127, "sign_extend supports up to 127 bits");
    let v = x & ((1u128 << bits) - 1);
    if v >> (bits - 1) & 1 == 1 {
        // Negative: compute 2^bits - v in u128 first — the magnitude is at
        // most 2^(bits-1) <= 2^126, so the cast cannot wrap even at the
        // 127-bit product width of the widest fused MAC.
        -(((1u128 << bits) - v) as i128)
    } else {
        v as i128
    }
}

/// Minimal JSON value for report emission (no parsing needed in-tree).
#[derive(Debug, Clone)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Number value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    /// Array value.
    pub fn arr(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }

    /// Serialize to compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl Json {
    /// Parse a JSON document (strict enough for round-tripping [`Json`]
    /// output; accepts standard JSON with arbitrary whitespace).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { text, bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Number payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }
    /// Bool payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs.as_slice()),
            _ => None,
        }
    }
    /// Object payload (sorted key map), if this is an object — the
    /// structural accessor the wire-protocol tests use to compare response
    /// envelopes key-by-key.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(map) => Some(map),
            _ => None,
        }
    }
    /// Object field access (`None` for missing key or non-object).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }
}

struct Parser<'a> {
    /// The input as a str (UTF-8 validity is established once, here).
    text: &'a str,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn eat_lit(&mut self, lit: &str) -> Result<(), String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(format!("expected '{lit}' at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => {
                self.eat_lit("null")?;
                Ok(Json::Null)
            }
            Some(b't') => {
                self.eat_lit("true")?;
                Ok(Json::Bool(true))
            }
            Some(b'f') => {
                self.eat_lit("false")?;
                Ok(Json::Bool(false))
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut xs = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                loop {
                    xs.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                        }
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(xs));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.eat(b':')?;
                    let val = self.value()?;
                    map.insert(key, val);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.pos += 1;
                        }
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(map));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
                    }
                }
            }
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    /// Four hex digits starting at `start`, as a code unit.
    fn hex4(&self, start: usize) -> Result<u32, String> {
        if start + 4 > self.bytes.len() {
            return Err("truncated \\u escape".to_string());
        }
        let hex = std::str::from_utf8(&self.bytes[start..start + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        u32::from_str_radix(hex, 16).map_err(|_| format!("bad \\u escape '{hex}'"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            let hi = self.hex4(self.pos + 1)?;
                            self.pos += 4;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: RFC 8259 pairs it with a
                                // following \uDC00-\uDFFF escape.
                                if self.bytes.get(self.pos + 1) != Some(&b'\\')
                                    || self.bytes.get(self.pos + 2) != Some(&b'u')
                                {
                                    return Err("unpaired high surrogate".to_string());
                                }
                                let lo = self.hex4(self.pos + 3)?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(format!("bad low surrogate {lo:#06x}"));
                                }
                                self.pos += 6;
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| format!("bad codepoint {code:#x}"))?,
                            );
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character. `text` was validated on
                    // entry and `pos` only ever lands on char boundaries
                    // (escapes are ASCII), so this is O(1) per char.
                    let c = self
                        .text
                        .get(self.pos..)
                        .and_then(|s| s.chars().next())
                        .ok_or_else(|| "invalid utf-8 boundary".to_string())?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }
}

/// Very small flag parser: `--key value` and `--switch` styles.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-flag arguments in order.
    pub positional: Vec<String>,
    /// `--key value` / `--flag` pairs.
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `--key value` / `--flag` style arguments.
    pub fn parse(argv: impl Iterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(a) = argv.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match argv.peek() {
                    Some(nxt) if !nxt.starts_with("--") => argv.next().unwrap(),
                    _ => "true".to_string(),
                };
                out.flags.insert(key.to_string(), val);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// `--key` parsed as usize, or `default`.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as f64, or `default`.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Whether `--key` was passed (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Simple fixed-width text table for the bench/report binaries.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }
    /// Append one row.
    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }
    /// Render as aligned plain text.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8));
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncol;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            fmt_row(r, &widths, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_extend_covers_edges() {
        assert_eq!(sign_extend(0, 0), 0);
        assert_eq!(sign_extend(0b101, 3), -3);
        assert_eq!(sign_extend(0b011, 3), 3);
        assert_eq!(sign_extend(0xFF, 4), -1); // masks to the low bits
        // 127-bit boundary (the widest fused-MAC product): MSB set means
        // v - 2^127, computed without i128 wrap.
        assert_eq!(sign_extend(1u128 << 126, 127), -(1i128 << 126));
        assert_eq!(sign_extend((1u128 << 127) - 1, 127), -1);
        assert_eq!(sign_extend((1u128 << 126) - 1, 127), (1i128 << 126) - 1);
    }

    #[test]
    fn rng_is_deterministic_and_uniformish() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        // mean of f64 samples near 0.5
        let mut r = Rng::seed_from_u64(123);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        // below() stays in range
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn json_escapes_and_nests() {
        let j = Json::obj(vec![
            ("name", Json::str("a\"b\\c\n")),
            ("xs", Json::arr(vec![Json::num(1.5), Json::Null, Json::Bool(true)])),
        ]);
        let s = j.render();
        assert_eq!(s, r#"{"name":"a\"b\\c\n","xs":[1.5,null,true]}"#);
    }

    #[test]
    fn json_parses_own_output() {
        let j = Json::obj(vec![
            ("name", Json::str("a\"b\\c\nμ")),
            ("xs", Json::arr(vec![Json::num(1.5), Json::Null, Json::Bool(true)])),
            ("neg", Json::num(-3.25e-2)),
            ("empty_arr", Json::arr(vec![])),
            ("empty_obj", Json::Obj(Default::default())),
        ]);
        let s = j.render();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.render(), s);
        assert_eq!(back.get("name").unwrap().as_str().unwrap(), "a\"b\\c\nμ");
        assert_eq!(back.get("neg").unwrap().as_f64().unwrap(), -3.25e-2);
        assert_eq!(back.get("xs").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn json_as_obj_accessor() {
        let j = Json::obj(vec![("a", Json::num(1.0)), ("b", Json::Null)]);
        let map = j.as_obj().unwrap();
        assert_eq!(map.keys().collect::<Vec<_>>(), ["a", "b"]);
        assert!(Json::Null.as_obj().is_none());
        assert!(Json::arr(vec![]).as_obj().is_none());
    }

    #[test]
    fn json_parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("true false").is_err());
    }

    #[test]
    fn json_parse_surrogate_pairs() {
        // RFC 8259 escaping of non-BMP characters (e.g. serde_json with
        // escape_non_ascii): "\ud83d\ude00" is U+1F600 (😀).
        let pair = "\"\\ud83d\\ude00\"";
        assert_eq!(Json::parse(pair).unwrap().as_str().unwrap(), "\u{1F600}");
        // BMP escapes and raw pass-through UTF-8 still work.
        assert_eq!(Json::parse("\"\\u00b5m\"").unwrap().as_str().unwrap(), "µm");
        assert_eq!(Json::parse("\"µm😀\"").unwrap().as_str().unwrap(), "µm😀");
        // Unpaired or malformed surrogates are rejected, not mangled.
        assert!(Json::parse("\"\\ud83d\"").is_err());
        assert!(Json::parse("\"\\ud83dA\"").is_err());
        assert!(Json::parse("\"\\ud83d\\u0041\"").is_err());
    }

    #[test]
    fn json_f64_roundtrip_is_exact() {
        // Rust's f64 Display prints the shortest round-tripping form, so
        // render → parse must be bit-exact for request fingerprints.
        for x in [0.1, 1.0 / 3.0, 6.02e23, -0.0, 5e-324, f64::MAX] {
            let s = Json::num(x).render();
            let y = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
    }

    #[test]
    fn args_parse_flags_and_positional() {
        let a = Args::parse(
            ["gen", "--width", "16", "--verbose", "--out", "x.json", "extra"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, vec!["gen", "extra"]);
        assert_eq!(a.get_usize("width", 8), 16);
        assert!(a.has("verbose"));
        assert_eq!(a.get("out"), Some("x.json"));
        assert_eq!(a.get_f64("missing", 2.5), 2.5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "area"]);
        t.row(vec!["ufo-mac".into(), "123.4".into()]);
        let s = t.render();
        assert!(s.contains("method"));
        assert!(s.contains("ufo-mac"));
    }
}
