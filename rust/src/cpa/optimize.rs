//! §4.3 / Algorithm 2 — timing-driven prefix-graph optimization.
//!
//! Sweeps bits MSB→LSB; for each bit whose estimated delay (input arrival
//! profile + FDC model over the extracted sub-prefix tree) violates the
//! target, applies one of the two Figure-9 transformations:
//!
//! - **depth-opt** — re-associate the deepest critical-path node
//!   (`GRAPHOPT`), trading a duplicated span for one level less depth;
//! - **fanout-opt** — the same re-association applied at the node whose
//!   non-trivial fan-in has the highest fanout, splitting a hot node.
//!
//! `GRAPHOPT(p)`: with `x = ntf(p)` internal, create `s = tf(p) ∘ tf(x)`
//! and rewire `p = s ∘ ntf(x)`. The graph is re-topologized after each
//! application (our IR keeps fan-ins before consumers).

use super::graph::{PIdx, PNode, PrefixGraph, NONE};
use super::timing::{fdc_features, FdcModel};

/// Per-bit delay estimate: an *arrival-aware* DP over the graph applying
/// the FDC cost model node by node — `est(node) = max(est(children)) +
/// k_type + k_fanout·(fanout − 1)` with leaves seeded by the input
/// arrival profile. This is the Eq.-27 model evaluated along real timing
/// paths rather than the depth-critical path, so Algorithm 2's
/// accept/reject decisions track the STA (fanout splits on early-but-hot
/// nodes are visible as improvements).
pub fn estimate_bit_delays(g: &PrefixGraph, arrivals: &[f64], model: &FdcModel) -> Vec<f64> {
    let fo = g.fanouts();
    let blue = super::timing::blue_mask(g);
    let mut est = vec![0.0f64; g.nodes.len()];
    for i in 0..g.nodes.len() {
        let nd = g.node(i);
        if nd.is_leaf() {
            // pg stage (half of the intercept) happens at the leaf.
            est[i] = arrivals.get(nd.msb).copied().unwrap_or(0.0) + model.b * 0.5;
        } else {
            let (k_node, k_fan) =
                if blue[i] { (model.k[3], model.k[1]) } else { (model.k[2], model.k[0]) };
            let cost = k_node + k_fan * (fo[i].saturating_sub(1)) as f64;
            est[i] = est[nd.tf].max(est[nd.ntf]) + cost;
        }
    }
    (0..g.n)
        .map(|bit| {
            let r = g.roots[bit];
            if r == NONE {
                0.0
            } else {
                // final sum XOR = the other half of the intercept.
                est[r] + model.b * 0.5
            }
        })
        .collect()
}

/// FDC-feature-based prediction per bit (Eq. 27 evaluated on the critical
/// path features) — kept for the Figure-8 fidelity study.
pub fn predict_bit_delays(g: &PrefixGraph, model: &FdcModel) -> Vec<f64> {
    fdc_features(g).iter().map(|f| model.predict(f)).collect()
}

/// Apply `GRAPHOPT` at node `p`. Returns false if `ntf(p)` is a leaf (no
/// transformation possible). The graph is re-topologized on success.
pub fn graphopt(g: &mut PrefixGraph, p: PIdx) -> bool {
    let pn = g.node(p);
    if pn.is_leaf() {
        return false;
    }
    let x = pn.ntf;
    let xn = g.node(x);
    if xn.is_leaf() {
        return false;
    }
    // s = tf(p) ∘ tf(x): spans [msb_p : lsb(tf(x))].
    let tf_p = g.node(pn.tf);
    let tf_x = g.node(xn.tf);
    debug_assert_eq!(tf_p.lsb, tf_x.msb + 1);
    let s = PNode { msb: tf_p.msb, lsb: tf_x.lsb, tf: pn.tf, ntf: xn.tf };
    g.nodes.push(s);
    let s_idx = g.nodes.len() - 1;
    g.nodes[p].tf = s_idx;
    g.nodes[p].ntf = xn.ntf;
    retopologize(g);
    true
}

/// Restore the fan-ins-before-consumers node order after in-place rewiring
/// (DFS from the roots; dead nodes dropped).
pub fn retopologize(g: &mut PrefixGraph) {
    let mut remap = vec![NONE; g.nodes.len()];
    let mut out: Vec<PNode> = Vec::with_capacity(g.nodes.len());
    for i in 0..g.n {
        remap[i] = i;
        out.push(g.nodes[i]);
    }
    // Iterative postorder.
    let mut stack: Vec<(PIdx, bool)> =
        g.roots.iter().filter(|&&r| r != NONE).map(|&r| (r, false)).collect();
    while let Some((i, expanded)) = stack.pop() {
        if remap[i] != NONE {
            continue;
        }
        let nd = g.nodes[i];
        if nd.is_leaf() {
            continue; // already mapped
        }
        if expanded {
            let mut m = nd;
            m.tf = remap[nd.tf];
            m.ntf = remap[nd.ntf];
            debug_assert!(m.tf != NONE && m.ntf != NONE, "child not mapped");
            remap[i] = out.len();
            out.push(m);
        } else {
            stack.push((i, true));
            stack.push((nd.tf, false));
            stack.push((nd.ntf, false));
        }
    }
    for r in g.roots.iter_mut() {
        if *r != NONE {
            *r = remap[*r];
        }
    }
    g.nodes = out;
}

/// Critical (deepest, fanout tie-break) path from `root` down to a leaf.
fn critical_path(g: &PrefixGraph, root: PIdx) -> Vec<PIdx> {
    let depths = g.depths();
    let fo = g.fanouts();
    let mut path = Vec::new();
    let mut cur = root;
    loop {
        path.push(cur);
        let nd = g.node(cur);
        if nd.is_leaf() {
            break;
        }
        let (dt, du) = (depths[nd.tf], depths[nd.ntf]);
        cur = if dt > du || (dt == du && fo[nd.tf] >= fo[nd.ntf]) { nd.tf } else { nd.ntf };
    }
    path
}

/// Nodes of the sub-prefix tree rooted at `root`.
fn subtree(g: &PrefixGraph, root: PIdx) -> Vec<PIdx> {
    let mut seen = vec![false; g.nodes.len()];
    let mut stack = vec![root];
    let mut out = Vec::new();
    while let Some(i) = stack.pop() {
        if seen[i] {
            continue;
        }
        seen[i] = true;
        out.push(i);
        let nd = g.node(i);
        if !nd.is_leaf() {
            stack.push(nd.tf);
            stack.push(nd.ntf);
        }
    }
    out
}

/// Outcome of one optimization run.
#[derive(Debug, Clone)]
pub struct OptReport {
    pub transforms: usize,
    pub met_all: bool,
    pub worst_delay_est: f64,
}

/// Algorithm 2: optimize `g` so each bit's estimated delay meets
/// `target_ns`, given the CT output `arrivals` profile.
pub fn optimize(
    g: &mut PrefixGraph,
    arrivals: &[f64],
    target_ns: f64,
    model: &FdcModel,
    max_transforms: usize,
) -> OptReport {
    let mut transforms = 0usize;
    // Track the best graph seen globally (a transform can improve its
    // target bit while regressing another; never return worse than start).
    let worst_of = |g: &PrefixGraph| {
        estimate_bit_delays(g, arrivals, model).iter().copied().fold(0.0f64, f64::max)
    };
    let mut best_graph = g.clone();
    let mut best_worst = worst_of(g);
    'outer: loop {
        let est = estimate_bit_delays(g, arrivals, model);
        let violated: Vec<usize> = (0..g.n).rev().filter(|&j| est[j] > target_ns + 1e-12).collect();
        if violated.is_empty() {
            break;
        }
        let mut improved_any = false;
        for j in violated {
            if transforms >= max_transforms {
                break 'outer;
            }
            let root = g.roots[j];
            if root == NONE {
                continue;
            }
            let depths = g.depths();
            let span = g.node(root).span();
            let min_depth = (span as f64).log2().ceil() as usize;
            let before = estimate_bit_delays(g, arrivals, model)[j];
            let snapshot = g.clone();
            // Line 7: depth-opt when depth exceeds the log2 bound (+1 for
            // LSB-side pg grouping); fanout-opt otherwise.
            let applied = if depths[root] > min_depth + 1 {
                // depth-opt: deepest critical-path node with internal ntf.
                let path = critical_path(g, root);
                let target = path
                    .iter()
                    .copied()
                    .filter(|&p| !g.node(p).is_leaf() && !g.node(g.node(p).ntf).is_leaf())
                    .max_by_key(|&p| depths[p]);
                target.map(|p| graphopt(g, p)).unwrap_or(false)
            } else {
                // fanout-opt: node whose ntf has the highest fanout (> 1).
                let fo = g.fanouts();
                let target = subtree(g, root)
                    .into_iter()
                    .filter(|&p| {
                        let nd = g.node(p);
                        !nd.is_leaf() && !g.node(nd.ntf).is_leaf() && fo[nd.ntf] > 1
                    })
                    .max_by_key(|&p| fo[g.node(p).ntf]);
                target.map(|p| graphopt(g, p)).unwrap_or(false)
            };
            if applied {
                let after = estimate_bit_delays(g, arrivals, model);
                if after[j] < before - 1e-12 {
                    transforms += 1;
                    improved_any = true;
                    let w = after.iter().copied().fold(0.0f64, f64::max);
                    if w < best_worst - 1e-12 {
                        best_worst = w;
                        best_graph = g.clone();
                    }
                } else {
                    // Non-improving transform: revert (keeps area in check
                    // and guarantees monotone progress / termination).
                    *g = snapshot;
                }
            }
        }
        if !improved_any {
            break;
        }
    }
    if worst_of(g) > best_worst + 1e-12 {
        *g = best_graph;
    }
    g.prune();
    let est = estimate_bit_delays(g, arrivals, model);
    let worst = est.iter().copied().fold(0.0f64, f64::max);
    OptReport {
        transforms,
        met_all: est.iter().all(|&e| e <= target_ns + 1e-9),
        worst_delay_est: worst,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpa::graph::{ripple, sklansky};
    use crate::cpa::netlist::standalone_adder;
    use crate::sim::{lane_value, pack_lanes, Simulator};

    fn check_adds(g: &PrefixGraph) {
        let n = g.n;
        let (nl, sum) = standalone_adder(g, None);
        nl.validate().unwrap();
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let mut sim = Simulator::new();
        let mask = (1u64 << n) - 1;
        let pairs: Vec<(u64, u64)> =
            (0..64).map(|_| (rng.next_u64() & mask, rng.next_u64() & mask)).collect();
        let assigns: Vec<Vec<bool>> = pairs
            .iter()
            .map(|(x, y)| (0..n).flat_map(|k| [x >> k & 1 != 0, y >> k & 1 != 0]).collect())
            .collect();
        let words = pack_lanes(&assigns);
        let vals = sim.run(&nl, &words).to_vec();
        for (lane, (x, y)) in pairs.iter().enumerate() {
            assert_eq!(lane_value(&vals, &sum, lane as u32), u128::from(x + y));
        }
    }

    #[test]
    fn graphopt_preserves_function_and_reduces_depth() {
        // On a ripple chain, repeated depth-opt must approach log depth.
        let mut g = ripple(16);
        let d0 = g.depth();
        let model = FdcModel::default_prior();
        let arrivals = vec![0.0; 16];
        optimize(&mut g, &arrivals, 0.0 /* unreachable target */, &model, 200);
        g.validate().unwrap();
        assert!(g.depth() < d0, "depth {} not reduced from {}", g.depth(), d0);
        check_adds(&g);
    }

    #[test]
    fn graphopt_single_step_valid() {
        let mut g = ripple(8);
        // root of bit 7 has ntf = root of bit 6 (internal) — transformable.
        let p = g.roots[7];
        assert!(graphopt(&mut g, p));
        g.validate().unwrap();
        check_adds(&g);
    }

    #[test]
    fn optimize_meets_loose_target_without_transforms() {
        let mut g = sklansky(16);
        let model = FdcModel::default_prior();
        let rep = optimize(&mut g, &vec![0.0; 16], 100.0, &model, 100);
        assert!(rep.met_all);
        assert_eq!(rep.transforms, 0);
    }

    #[test]
    fn optimize_respects_arrival_profile() {
        // Late-arriving middle bits (the CT trapezoid) drive estimates.
        let arr: Vec<f64> =
            (0..16).map(|i| if (4..12).contains(&i) { 0.3 } else { 0.1 }).collect();
        let g = ripple(16);
        let model = FdcModel::default_prior();
        let est = estimate_bit_delays(&g, &arr, &model);
        // Bit 15's subtree includes the late middle bits ⇒ est must exceed
        // the model-only delay.
        let est0 = estimate_bit_delays(&g, &vec![0.0; 16], &model);
        assert!(est[15] > est0[15]);
    }

    #[test]
    fn fanout_opt_splits_hot_nodes() {
        // One fanout-opt application at the node whose ntf is hottest must
        // lower that ntf's fanout by one and preserve the function.
        let mut g = sklansky(32);
        let fo = g.fanouts();
        let (p, hot_span, hot_fo) = (g.n..g.nodes.len())
            .filter(|&p| {
                let nd = g.node(p);
                !g.node(nd.ntf).is_leaf() && fo[nd.ntf] > 1
            })
            .map(|p| {
                let x = g.node(p).ntf;
                (p, (g.node(x).msb, g.node(x).lsb), fo[x])
            })
            .max_by_key(|&(_, _, f)| f)
            .unwrap();
        assert!(graphopt(&mut g, p));
        g.validate().unwrap();
        // The hot span's total fanout (summed over duplicates) dropped.
        let fo2 = g.fanouts();
        let hot_fo_after: usize = (g.n..g.nodes.len())
            .filter(|&i| (g.node(i).msb, g.node(i).lsb) == hot_span)
            .map(|i| fo2[i])
            .max()
            .unwrap_or(0);
        assert!(hot_fo_after < hot_fo, "hot fanout {hot_fo}→{hot_fo_after}");
        check_adds(&g);
    }

    #[test]
    fn optimize_with_unreachable_target_terminates_and_stays_correct() {
        let mut g = sklansky(32);
        let model = FdcModel::default_prior();
        let rep = optimize(&mut g, &vec![0.0; 32], 0.0, &model, 64);
        assert!(!rep.met_all);
        g.validate().unwrap();
        check_adds(&g);
    }
}
