//! Minimal work-stealing-free thread pool (std-only; the image vendors no
//! async runtime). Jobs are closures producing `T`; results arrive in
//! completion order through an mpsc channel.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job<T> = Box<dyn FnOnce() -> T + Send + 'static>;

/// Run `jobs` on `workers` threads, returning results in completion order.
pub fn run_jobs<T: Send + 'static>(workers: usize, jobs: Vec<Job<T>>) -> Vec<T> {
    let workers = workers.max(1);
    let queue = Arc::new(Mutex::new(jobs));
    let (tx, rx) = mpsc::channel();
    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || loop {
            let job = { queue.lock().unwrap().pop() };
            match job {
                Some(j) => {
                    // A panicking job poisons nothing: catch and skip.
                    if let Ok(v) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(j)) {
                        let _ = tx.send(v);
                    }
                }
                None => break,
            }
        }));
    }
    drop(tx);
    let results: Vec<T> = rx.into_iter().collect();
    for h in handles {
        let _ = h.join();
    }
    results
}

/// Convenience: map a function over items in parallel.
pub fn par_map<I, T, F>(workers: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send + 'static,
    T: Send + 'static,
    F: Fn(I) -> T + Send + Sync + Clone + 'static,
{
    let jobs: Vec<Job<T>> = items
        .into_iter()
        .map(|item| {
            let f = f.clone();
            Box::new(move || f(item)) as Job<T>
        })
        .collect();
    run_jobs(workers, jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let mut out = par_map(4, (0..100).collect::<Vec<i32>>(), |x| x * 2);
        out.sort();
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_works() {
        let out = par_map(1, vec![1, 2, 3], |x| x + 1);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn panicking_job_is_skipped() {
        let out = par_map(2, vec![0, 1, 2, 3], |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
        assert_eq!(out.len(), 3);
    }
}
