//! Long-lived design-compilation service in front of a [`SynthEngine`].
//!
//! The server speaks newline-delimited JSON (`PROTOCOL.md` at the
//! repository root is the normative wire description): each input line is
//! one command (`compile`, `batch`, `lint`, `analyze`, `sweep`, `stats`,
//! `shutdown`), each
//! output line one response envelope carrying the echoed request `id`.
//! Commands are dispatched concurrently over
//! [`crate::coordinator::pool::scoped_workers`], so a slow `sweep` does not
//! block a `stats` probe; responses therefore arrive in *completion* order
//! and clients correlate them by `id`.
//!
//! Three properties make the service cheap to hit repeatedly:
//!
//! - **content-addressed caching** — identical requests (any spelling, see
//!   [`DesignRequest::canonical`]) resolve to one cache entry;
//! - **in-flight coalescing** — N simultaneous identical compiles trigger
//!   exactly one synthesis ([`SynthEngine::compile_traced`]);
//! - **a persistent disk tier** — engines built with
//!   [`EngineConfig::cache_dir`](crate::api::EngineConfig) write every
//!   artifact through to checksummed entry files, so warm designs survive
//!   restarts and a fresh process answers them from disk (`"source":
//!   "disk"` in the response) without recompiling.
//!
//! ```
//! use std::sync::Arc;
//! use ufo_mac::api::{EngineConfig, SynthEngine};
//! use ufo_mac::server::Server;
//!
//! let server = Server::new(Arc::new(SynthEngine::new(EngineConfig::default())));
//! let resp = server.handle_line(
//!     r#"{"cmd":"compile","id":1,"request":{"kind":"method","method":"ufo","n":4,"strategy":"tradeoff","mac":false}}"#,
//! );
//! assert!(resp.contains(r#""ok":true"#) && resp.contains(r#""source":"compiled""#));
//! ```

mod protocol;

pub use protocol::Command;

use crate::api::{DesignRequest, SynthEngine};
use crate::coordinator::{self, pool};
use crate::sta::TimingStats;
use crate::util::Json;
use crate::Result;
use anyhow::anyhow;
use protocol::{analysis_summary, artifact_summary, envelope_err, envelope_ok, lint_summary};
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// The design-compilation server (see module docs).
pub struct Server {
    engine: Arc<SynthEngine>,
    /// Requests admitted to the queue but not yet answered.
    queue_depth: AtomicUsize,
    /// Responses written over the server's lifetime.
    served: AtomicU64,
    /// Aggregate timing-evaluation work behind the artifacts this server
    /// compiled or served (`compile`/`batch` commands).
    timing: Mutex<TimingStats>,
}

impl Server {
    /// Wrap an engine. The engine is shared — several servers (or a server
    /// plus direct API callers) may compile through one engine and its
    /// cache.
    pub fn new(engine: Arc<SynthEngine>) -> Server {
        Server {
            engine,
            queue_depth: AtomicUsize::new(0),
            served: AtomicU64::new(0),
            timing: Mutex::new(TimingStats::default()),
        }
    }

    /// The engine this server compiles through.
    pub fn engine(&self) -> &Arc<SynthEngine> {
        &self.engine
    }

    /// Process one request line and return the response line (no trailing
    /// newline). This is the whole protocol for one command; the loops in
    /// [`Server::serve`]/[`Server::serve_tcp`] are plumbing around it.
    pub fn handle_line(&self, line: &str) -> String {
        self.respond(line).0
    }

    /// Handle one line; the flag reports whether the command asks the
    /// serving loop to stop (`shutdown`).
    fn respond(&self, line: &str) -> (String, bool) {
        let (id, cmd) = protocol::parse_line(line);
        let cmd = match cmd {
            Ok(cmd) => cmd,
            Err(e) => return (envelope_err(&id, &format!("{e:#}")).render(), false),
        };
        let shutdown = matches!(cmd, Command::Shutdown);
        let result = self.dispatch(cmd);
        let envelope = match result {
            Ok(result) => envelope_ok(&id, result),
            Err(e) => envelope_err(&id, &format!("{e:#}")),
        };
        (envelope.render(), shutdown)
    }

    fn dispatch(&self, cmd: Command) -> Result<Json> {
        match cmd {
            Command::Compile(req) => {
                // Contain synthesis panics to this command (as `batch`
                // does per row): one poison request must produce an error
                // envelope, not tear down the serving loop.
                let (art, source) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || self.engine.compile_traced(&req),
                ))
                .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")))?;
                self.timing.lock().unwrap().merge(&art.timing);
                Ok(artifact_summary(&art, source))
            }
            Command::Batch(reqs) => {
                let rows = self.engine.compile_batch_traced(&reqs);
                let mut out = Vec::with_capacity(rows.len());
                for row in rows {
                    out.push(match row {
                        Ok((art, source)) => {
                            self.timing.lock().unwrap().merge(&art.timing);
                            Json::obj(vec![
                                ("ok", Json::Bool(true)),
                                ("result", artifact_summary(&art, source)),
                            ])
                        }
                        Err(e) => Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", Json::str(format!("{e:#}"))),
                        ]),
                    });
                }
                Ok(Json::obj(vec![
                    ("count", Json::num(out.len() as f64)),
                    ("results", Json::Arr(out)),
                ]))
            }
            Command::Lint(req) => {
                // Same panic containment as `compile`: linting an uncached
                // request synthesizes it first.
                let (report, art, source) = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| self.engine.lint(&req)),
                )
                .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")))?;
                self.timing.lock().unwrap().merge(&art.timing);
                Ok(lint_summary(&report, &art, source))
            }
            Command::Analyze(req) => {
                // Same panic containment as `lint`: analyzing an uncached
                // request synthesizes it first.
                let (report, art, source) = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| self.engine.analyze(&req)),
                )
                .unwrap_or_else(|_| Err(anyhow!("synthesis panicked for {req:?}")))?;
                self.timing.lock().unwrap().merge(&art.timing);
                Ok(analysis_summary(&report, &art, source))
            }
            Command::Sweep(cfg) => {
                let points = coordinator::run_sweep_with(&self.engine, &cfg);
                Ok(Json::obj(vec![
                    ("count", Json::num(points.len() as f64)),
                    ("points", coordinator::points_json(&points)),
                ]))
            }
            Command::Stats => Ok(self.stats_json()),
            Command::Shutdown => Ok(Json::str("shutting down")),
        }
    }

    /// The `stats` response body.
    fn stats_json(&self) -> Json {
        let s = self.engine.cache_stats();
        let t = *self.timing.lock().unwrap();
        Json::obj(vec![
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::num(s.hits as f64)),
                    ("disk_hits", Json::num(s.disk_hits as f64)),
                    ("misses", Json::num(s.misses as f64)),
                    ("coalesced", Json::num(s.coalesced as f64)),
                    ("entries", Json::num(s.entries as f64)),
                    ("hit_rate", Json::num(s.hit_rate())),
                ]),
            ),
            (
                "timing",
                Json::obj(vec![
                    ("full_passes", Json::num(t.full_passes as f64)),
                    ("incremental_passes", Json::num(t.incremental_passes as f64)),
                    ("nodes_retimed", Json::num(t.nodes_retimed as f64)),
                    ("nodes_total", Json::num(t.nodes_total as f64)),
                    ("retime_fraction", Json::num(t.retime_fraction())),
                ]),
            ),
            ("queue_depth", Json::num(self.queue_depth.load(Ordering::Relaxed) as f64)),
            ("served", Json::num(self.served.load(Ordering::Relaxed) as f64)),
            ("workers", Json::num(self.engine.config().workers as f64)),
        ])
    }

    /// Serve newline-delimited JSON from `reader` to `writer` with
    /// `workers` concurrent command handlers (plus one reader thread), all
    /// on [`pool::scoped_workers`]. Returns when the input reaches EOF or
    /// the stream errors. After a `shutdown` command has been answered the
    /// queue is drained and the loop stops at the reader's *next* wakeup —
    /// immediate for transports with a read timeout (the TCP listener sets
    /// one), at the next line/EOF for a plain blocking reader such as
    /// stdin. Piped stdio clients therefore need no explicit `shutdown`:
    /// closing the pipe is enough.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use ufo_mac::api::{EngineConfig, SynthEngine};
    /// use ufo_mac::server::Server;
    ///
    /// let server = Server::new(Arc::new(SynthEngine::new(EngineConfig::default())));
    /// let input: &[u8] = b"{\"cmd\":\"stats\",\"id\":1}\n";
    /// let mut output = Vec::new();
    /// server.serve(input, &mut output, 2)?;
    /// assert!(String::from_utf8(output)?.contains(r#""ok":true"#));
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn serve<R, W>(&self, reader: R, writer: W, workers: usize) -> Result<()>
    where
        R: BufRead + Send,
        W: Write + Send,
    {
        let workers = workers.max(1);
        let stop = AtomicBool::new(false);
        let closed = AtomicBool::new(false);
        let queue: Mutex<VecDeque<String>> = Mutex::new(VecDeque::new());
        let ready = Condvar::new();
        let writer = Mutex::new(writer);
        let reader_cell = Mutex::new(Some(reader));
        // Worker 0 is the reader; workers 1..=N handle commands.
        pool::scoped_workers(workers + 1, |w| {
            if w == 0 {
                let mut reader = reader_cell.lock().unwrap().take().expect("one reader");
                let mut buf = String::new();
                loop {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match reader.read_line(&mut buf) {
                        Ok(0) => break, // EOF
                        Ok(_) => {
                            let line = buf.trim();
                            if !line.is_empty() {
                                self.queue_depth.fetch_add(1, Ordering::Relaxed);
                                queue.lock().unwrap().push_back(line.to_string());
                                ready.notify_one();
                            }
                            buf.clear();
                        }
                        // Read timeouts (the TCP transport polls so a
                        // shutdown can close the connection) keep any
                        // partial line in `buf` and try again.
                        Err(e)
                            if matches!(
                                e.kind(),
                                std::io::ErrorKind::WouldBlock
                                    | std::io::ErrorKind::TimedOut
                                    | std::io::ErrorKind::Interrupted
                            ) => {}
                        Err(_) => break,
                    }
                }
                closed.store(true, Ordering::Relaxed);
                ready.notify_all();
            } else {
                loop {
                    let line = {
                        let mut q = queue.lock().unwrap();
                        loop {
                            if let Some(line) = q.pop_front() {
                                break Some(line);
                            }
                            if closed.load(Ordering::Relaxed) || stop.load(Ordering::Relaxed) {
                                break None;
                            }
                            q = ready.wait(q).unwrap();
                        }
                    };
                    let Some(line) = line else { break };
                    let (resp, shutdown) = self.respond(&line);
                    {
                        let mut w = writer.lock().unwrap();
                        let _ = writeln!(w, "{resp}");
                        let _ = w.flush();
                    }
                    self.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    self.served.fetch_add(1, Ordering::Relaxed);
                    if shutdown {
                        stop.store(true, Ordering::Relaxed);
                        ready.notify_all();
                    }
                }
            }
        });
        Ok(())
    }

    /// Accept TCP connections forever, serving each connection with
    /// [`Server::serve`] on its own thread (connections are concurrent and
    /// share the engine's cache). A `shutdown` command ends its own
    /// connection; the listener keeps accepting.
    pub fn serve_listener(&self, listener: TcpListener) -> Result<()> {
        std::thread::scope(|s| {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { continue };
                s.spawn(move || {
                    // Poll reads so a served `shutdown` actually closes the
                    // connection instead of blocking on the next line.
                    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
                    let Ok(rd) = stream.try_clone() else { return };
                    let workers = self.engine.config().workers;
                    let _ = self.serve(BufReader::new(rd), stream, workers);
                });
            }
        });
        Ok(())
    }

    /// Bind `addr` and [`Server::serve_listener`] on it. Prints one
    /// "listening" line to stdout and then runs until the process is
    /// killed.
    ///
    /// ```no_run
    /// use std::sync::Arc;
    /// use ufo_mac::api::{EngineConfig, SynthEngine};
    /// use ufo_mac::server::Server;
    ///
    /// let engine = Arc::new(SynthEngine::new(EngineConfig {
    ///     cache_dir: Some(ufo_mac::runtime::default_cache_dir()),
    ///     ..EngineConfig::default()
    /// }));
    /// Server::new(engine).serve_tcp("127.0.0.1:7878")?;
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn serve_tcp(&self, addr: &str) -> Result<()> {
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow!("cannot bind '{addr}': {e}"))?;
        let local = listener.local_addr()?;
        println!("ufo-mac serve: listening on {local} (newline-delimited JSON, see PROTOCOL.md)");
        self.serve_listener(listener)
    }
}

/// Convenience used by tests and examples: render one `compile` request
/// line (NDJSON) for `req` with the given `id`.
pub fn compile_line(id: u64, req: &DesignRequest) -> String {
    Json::obj(vec![
        ("cmd", Json::str("compile")),
        ("id", Json::num(id as f64)),
        ("request", req.to_json()),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EngineConfig;

    fn server() -> Server {
        Server::new(Arc::new(SynthEngine::new(EngineConfig::default())))
    }

    #[test]
    fn unknown_cmd_lists_valid_values() {
        let resp = server().handle_line(r#"{"cmd":"warp","id":9}"#);
        assert!(resp.contains(r#""ok":false"#), "{resp}");
        assert!(
            resp.contains("valid: analyze, batch, compile, lint, shutdown, stats, sweep"),
            "{resp}"
        );
        assert!(resp.contains(r#""id":9"#), "{resp}");
    }

    #[test]
    fn malformed_line_is_an_error_envelope() {
        let resp = server().handle_line("not json at all");
        assert!(resp.contains(r#""ok":false"#), "{resp}");
        assert!(resp.contains(r#""id":null"#), "{resp}");
    }

    #[test]
    fn compile_then_hit_then_stats() {
        let srv = server();
        let req = DesignRequest::multiplier(4);
        let first = srv.handle_line(&compile_line(1, &req));
        assert!(first.contains(r#""source":"compiled""#), "{first}");
        let second = srv.handle_line(&compile_line(2, &req));
        assert!(second.contains(r#""source":"memory""#), "{second}");
        let stats = srv.handle_line(r#"{"cmd":"stats","id":3}"#);
        let doc = Json::parse(&stats).unwrap();
        let cache = doc.get("result").unwrap().get("cache").unwrap();
        assert!(cache.get("hits").unwrap().as_f64().unwrap() >= 1.0, "{stats}");
    }

    #[test]
    fn lint_reports_clean_design_with_cache_provenance() {
        let srv = server();
        let line = r#"{"cmd":"lint","id":4,"request":{"kind":"method","method":"ufo","n":4,"strategy":"tradeoff","mac":false}}"#;
        let resp = srv.handle_line(line);
        assert!(resp.contains(r#""ok":true"#), "{resp}");
        assert!(resp.contains(r#""clean":true"#), "{resp}");
        assert!(resp.contains(r#""source":"compiled""#), "{resp}");
        // A `compile` of the same request shares the cache entry, so the
        // second lint is a memory hit.
        let again = srv.handle_line(line);
        assert!(again.contains(r#""source":"memory""#), "{again}");
    }

    #[test]
    fn analyze_reports_proven_constants_with_cache_provenance() {
        let srv = server();
        let line = r#"{"cmd":"analyze","id":5,"request":{"kind":"method","method":"ufo","n":4,"strategy":"tradeoff","mac":false}}"#;
        let resp = srv.handle_line(line);
        assert!(resp.contains(r#""ok":true"#), "{resp}");
        assert!(resp.contains(r#""proven_const""#), "{resp}");
        assert!(resp.contains(r#""mean_activity""#), "{resp}");
        assert!(resp.contains(r#""source":"compiled""#), "{resp}");
        // A repeat shares the cache entry (and its stored report).
        let again = srv.handle_line(line);
        assert!(again.contains(r#""source":"memory""#), "{again}");
    }

    #[test]
    fn sweep_rejects_unknown_axis_values_strictly() {
        let srv = server();
        let resp = srv.handle_line(r#"{"cmd":"sweep","id":1,"methods":["alien"]}"#);
        assert!(resp.contains("valid: ufo, gomil, rlmul, commercial"), "{resp}");
        let resp = srv.handle_line(r#"{"cmd":"sweep","id":1,"strategies":["fast"]}"#);
        assert!(resp.contains("valid: area, timing, tradeoff"), "{resp}");
        let resp = srv.handle_line(r#"{"cmd":"sweep","id":1,"signedness":["sorta"]}"#);
        assert!(resp.contains("valid: signed, unsigned"), "{resp}");
    }
}
