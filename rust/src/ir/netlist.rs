//! Gate-level netlist IR — flat struct-of-arrays storage.
//!
//! A [`Netlist`] is a topologically-ordered DAG of standard cells over
//! primary inputs and constants. Nodes are created append-only and may only
//! reference already-created nodes, so every forward pass (simulation, STA,
//! power) is a single linear sweep — the property the coordinator's hot
//! paths rely on.
//!
//! ## Storage layout (EXPERIMENTS.md §Perf)
//!
//! Nodes are stored as parallel flat arrays rather than one enum value per
//! node: an opcode byte and an inline `[u32; 3]` fanin record per node, one
//! arrival-time entry per *input* (indexed by input ordinal, not node id),
//! and every input/output name interned into a single string table. There
//! is no per-gate heap allocation and no enum match in hot loops: the
//! simulator borrows the arrays zero-copy ([`crate::sim::CompiledNetlist`]),
//! both STA engines sweep them directly, and the PJRT / persistence
//! encodings copy them out column-wise. The [`Node`] *view* type
//! reconstructs the classic enum shape on demand for code that prefers
//! readability over throughput (Verilog export, serialization, tests).
//!
//! ## Cached topology
//!
//! Derived topology — CSR fanout adjacency, fanout counts, logic depths,
//! max depth over outputs — is built lazily on first use and shared behind
//! an `Arc` ([`Netlist::topology`]): [`crate::sta::Sta::analyze`] serves
//! depth from it and [`crate::sta::IncrementalSta`] walks its CSR
//! consumers, so every STA-scored pass over one netlist reuses one build
//! instead of re-deriving adjacency/depths itself.
//! Invalidation rules: structural edits ([`Netlist::gate`],
//! [`Netlist::input`], [`Netlist::constant`], [`Netlist::output`])
//! invalidate the cache; [`Netlist::set_input_arrival`] does **not**,
//! because arrival times live outside the topology — which is what keeps
//! the optimization-move loop (shift one arrival, re-time the cone)
//! entirely allocation-free.

use super::cell::{CellKind, CellLib};

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Opcode marking a constant-0 node in the flat encoding (gate opcodes are
/// [`CellKind::opcode`], 0–10). Shared with [`crate::sim`] and the PJRT
/// artifact encoding in [`crate::runtime`].
pub const OP_CONST0: u8 = 11;
/// Opcode marking a constant-1 node in the flat encoding.
pub const OP_CONST1: u8 = 12;
/// Opcode marking a primary input in the flat encoding; the first slot of
/// its fanin record holds the input *ordinal* (index into the arrival and
/// name arrays), not a node id.
pub const OP_INPUT: u8 = 13;
/// Opcode marking a clocked register (D flip-flop with synchronous enable
/// and clear) in the flat encoding. The fanin record is `[d, en, clr]`; the
/// reset/init value lives in a side array ([`Netlist::reg_init`]) because
/// the inline record has no spare slot. Registers are *sequential cut
/// points*: the topology gives them depth 0, STA restarts arrivals at the
/// clock edge ([`crate::sta`]), and — uniquely in the IR — the `d` fanin
/// may reference a *later* node, which is how sequential feedback
/// (accumulators) flattens into the otherwise append-only arrays. `en` and
/// `clr` must still reference earlier nodes: control has to settle from
/// this cycle's values before the edge.
pub const OP_REG: u8 = 14;

/// Index of a node (primary input, constant, or gate output) in a netlist.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(transparent)]
pub struct NodeId(pub u32);

impl NodeId {
    #[inline]
    /// The node's position in the netlist.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// A read-only view of one netlist node, reconstructed from the flat
/// arrays. Cheap to build (no allocation); hot loops should read the flat
/// arrays directly via [`Netlist::ops`] / [`Netlist::fanin_records`].
#[derive(Debug, Clone, Copy)]
pub enum Node<'a> {
    /// Primary input with an externally supplied arrival time (ns).
    Input {
        /// Interned input name.
        name: &'a str,
        /// Arrival time in ns.
        arrival_ns: f64,
    },
    /// Constant 0 / 1.
    Const(bool),
    /// A standard cell instance; `fanin.len() == kind.arity()`.
    Gate {
        /// Cell function.
        kind: CellKind,
        /// Fanin node ids (length = arity).
        fanin: &'a [NodeId],
    },
    /// A clocked register (see [`OP_REG`] for the cut-point semantics).
    /// Per clock edge: `q ← clr ? init : (en ? d : q)`.
    Reg {
        /// Data input (may reference a later node: sequential feedback).
        d: NodeId,
        /// Synchronous enable (1 = capture `d`).
        en: NodeId,
        /// Synchronous clear (1 = load `init`; priority over `en`).
        clr: NodeId,
        /// Reset / clear value.
        init: bool,
    },
}

/// Interned string storage: every name lives in one backing `String`, so a
/// netlist with thousands of input/output names costs two allocations, not
/// thousands.
#[derive(Debug, Clone, Default)]
struct StrTable {
    data: String,
    ends: Vec<u32>,
}

impl StrTable {
    fn intern(&mut self, s: &str) -> u32 {
        self.data.push_str(s);
        self.ends.push(self.data.len() as u32);
        (self.ends.len() - 1) as u32
    }

    fn get(&self, id: u32) -> &str {
        let i = id as usize;
        let end = self.ends[i] as usize;
        let start = if i == 0 { 0 } else { self.ends[i - 1] as usize };
        &self.data[start..end]
    }
}

/// Lazily built, edit-invalidated topology cache slot.
type TopoCell = Mutex<Option<Arc<Topology>>>;

/// Derived topology of one netlist, built once and shared by every
/// analysis pass ([`crate::sta::Sta::analyze`],
/// [`crate::sta::IncrementalSta`], power extraction): CSR fanout
/// adjacency, fanout counts, per-node logic depths and the max depth over
/// primary outputs. Obtained from [`Netlist::topology`]; structural edits
/// invalidate the netlist's cached copy, arrival edits do not.
#[derive(Debug)]
pub struct Topology {
    /// Fanout count per node (gate-input references + one per primary
    /// output registration).
    fanout: Vec<u32>,
    /// CSR row offsets into `consumers` (length = nodes + 1). Rows cover
    /// *gate* consumers only; primary outputs are counted in `fanout` but
    /// have no consumer entry.
    offsets: Vec<u32>,
    /// CSR payload: for each node, the gate nodes reading it, in
    /// increasing topological order (duplicates kept for gates sampling
    /// one driver twice).
    consumers: Vec<u32>,
    /// Logic depth (gate count) per node; inputs/constants/registers are
    /// depth 0 (registers are sequential cut points).
    depths: Vec<u32>,
    /// Maximum logic depth over sequential endpoints: primary outputs and
    /// register data pins (the deepest combinational segment).
    depth: u32,
}

impl Topology {
    /// Gate nodes that read node `i` (duplicates allowed for gates
    /// sampling one driver twice), in topological order.
    #[inline]
    pub fn consumers(&self, i: usize) -> &[u32] {
        &self.consumers[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Fanout count per node (number of gate inputs each node drives;
    /// primary outputs add `1` each).
    #[inline]
    pub fn fanout_counts(&self) -> &[u32] {
        &self.fanout
    }

    /// Logic depth (gate count) per node; inputs/constants are depth 0.
    #[inline]
    pub fn depths(&self) -> &[u32] {
        &self.depths
    }

    /// Maximum logic depth over sequential endpoints (primary outputs and
    /// register data pins) — the deepest combinational segment.
    #[inline]
    pub fn depth(&self) -> u32 {
        self.depth
    }

    /// All node ids grouped by logic depth, ascending id within each
    /// level. Level 0 holds the inputs, constants and registers; every
    /// gate sits strictly above all of its fanins, so evaluating level by
    /// level is a valid forward schedule — and within one level every
    /// node is independent, which is what the parallel dataflow sweeps in
    /// [`crate::analysis`] exploit.
    pub fn levels(&self) -> Vec<Vec<u32>> {
        let maxd = self.depths.iter().copied().max().unwrap_or(0) as usize;
        let mut levels = vec![Vec::new(); maxd + 1];
        for (i, &d) in self.depths.iter().enumerate() {
            levels[d as usize].push(i as u32);
        }
        levels
    }
}

/// Gate-level netlist with named primary outputs, stored as flat
/// struct-of-arrays (see the module docs for the layout).
#[derive(Debug, Default)]
pub struct Netlist {
    /// Diagnostic name (used in error messages and reports).
    pub name: String,
    /// Opcode per node: 0–10 = [`CellKind::opcode`], [`OP_CONST0`],
    /// [`OP_CONST1`], [`OP_INPUT`], [`OP_REG`].
    ops: Vec<u8>,
    /// Inline fanin record per node. Gates: fanin node ids in slots
    /// `0..arity` (rest zero). Inputs: slot 0 holds the input ordinal.
    /// Registers: `[d, en, clr]`. Constants: all zero.
    fanin: Vec<[u32; 3]>,
    /// Node id per input ordinal, in creation order.
    input_ids: Vec<NodeId>,
    /// Arrival time (ns) per input ordinal.
    input_arrivals: Vec<f64>,
    /// Interned input and output names.
    names: StrTable,
    /// Interned name id per input ordinal.
    input_names: Vec<u32>,
    /// `(interned name, node)` per primary output, in registration order.
    outputs: Vec<(u32, NodeId)>,
    /// Gate count (excludes inputs/constants/registers), maintained eagerly.
    n_gates: usize,
    /// `(node id, init value)` per register, in creation order (node ids
    /// strictly increasing, so lookup is a binary search). The init bit has
    /// no slot in the inline fanin record.
    reg_inits: Vec<(u32, bool)>,
    /// Lazily built topology (see [`Netlist::topology`]).
    topo: TopoCell,
}

impl Clone for Netlist {
    fn clone(&self) -> Self {
        Netlist {
            name: self.name.clone(),
            ops: self.ops.clone(),
            fanin: self.fanin.clone(),
            input_ids: self.input_ids.clone(),
            input_arrivals: self.input_arrivals.clone(),
            names: self.names.clone(),
            input_names: self.input_names.clone(),
            outputs: self.outputs.clone(),
            n_gates: self.n_gates,
            reg_inits: self.reg_inits.clone(),
            // The clone rebuilds its topology lazily on first use.
            topo: Mutex::new(None),
        }
    }
}

impl Netlist {
    /// Empty netlist with a diagnostic name.
    pub fn new(name: impl Into<String>) -> Self {
        Netlist { name: name.into(), ..Default::default() }
    }

    /// Reserve capacity for at least `additional` more nodes in the
    /// per-node arrays (`ops`/`fanin`). Builders that can bound their gate
    /// count up front (the PPG → CT → CPA pipeline sizes itself from the
    /// partial-product matrix and the [`crate::ct::StagePlan`] compressor
    /// counts) call this once so node insertion never reallocates
    /// mid-build — the dominant allocator cost in `netlist_build_64x64`
    /// (EXPERIMENTS.md §Perf). Over-estimates only cost transient
    /// capacity; the estimate does not need to be exact.
    pub fn reserve(&mut self, additional: usize) {
        self.ops.reserve(additional);
        self.fanin.reserve(additional);
    }

    /// Current node capacity of the per-node arrays (for tests and
    /// allocation diagnostics).
    pub fn capacity(&self) -> usize {
        self.ops.capacity().min(self.fanin.capacity())
    }

    /// Reset the cached topology after a structural edit.
    fn invalidate(&mut self) {
        match self.topo.get_mut() {
            Ok(slot) => *slot = None,
            Err(poisoned) => *poisoned.into_inner() = None,
        }
    }

    /// Add a primary input arriving at t=0.
    pub fn input(&mut self, name: impl Into<String>) -> NodeId {
        self.input_at(name, 0.0)
    }

    /// Add a primary input with a non-zero arrival time (ns) — the mechanism
    /// behind the paper's non-uniform CPA arrival profiles.
    pub fn input_at(&mut self, name: impl Into<String>, arrival_ns: f64) -> NodeId {
        let id = NodeId(self.ops.len() as u32);
        let ordinal = self.input_ids.len() as u32;
        self.ops.push(OP_INPUT);
        self.fanin.push([ordinal, 0, 0]);
        self.input_ids.push(id);
        self.input_arrivals.push(arrival_ns);
        let name_id = self.names.intern(&name.into());
        self.input_names.push(name_id);
        self.invalidate();
        id
    }

    /// Change the arrival time (ns) of an existing primary input — the
    /// mutation an optimization move makes when an upstream change (a CT
    /// interconnect swap, a revised column profile) shifts when this
    /// input's data shows up. [`crate::sta::IncrementalSta`] re-times only
    /// the input's fan-out cone after such an edit. Arrival times live
    /// outside the topology, so this edit does **not** invalidate the
    /// cached [`Topology`]. Panics if `id` is not an input.
    pub fn set_input_arrival(&mut self, id: NodeId, arrival_ns: f64) {
        let i = id.index();
        if self.ops[i] != OP_INPUT {
            panic!("set_input_arrival on non-input node {:?}", self.view(i));
        }
        let ordinal = self.fanin[i][0] as usize;
        self.input_arrivals[ordinal] = arrival_ns;
    }

    /// Add a constant node.
    pub fn constant(&mut self, value: bool) -> NodeId {
        let id = NodeId(self.ops.len() as u32);
        self.ops.push(if value { OP_CONST1 } else { OP_CONST0 });
        self.fanin.push([0, 0, 0]);
        self.invalidate();
        id
    }

    /// Instantiate a gate. Panics if arity mismatches or a fanin is a
    /// forward reference (which would break topological order).
    pub fn gate(&mut self, kind: CellKind, fanin: &[NodeId]) -> NodeId {
        assert_eq!(fanin.len(), kind.arity(), "{kind:?} arity");
        let id = NodeId(self.ops.len() as u32);
        let mut rec = [0u32; 3];
        for (k, f) in fanin.iter().enumerate() {
            assert!(f.0 < id.0, "fanin {f:?} is a forward reference");
            rec[k] = f.0;
        }
        self.ops.push(kind.opcode() as u8);
        self.fanin.push(rec);
        self.n_gates += 1;
        self.invalidate();
        id
    }

    /// Instantiate a clocked register `q ← clr ? init : (en ? d : q)` with
    /// all three fanins already built (the feed-forward form every
    /// pipeline cut uses). For sequential feedback — a `d` that does not
    /// exist yet — create the register with a provisional `d` (itself, via
    /// [`Netlist::reg`] after the fact is impossible append-only) and patch
    /// it with [`Netlist::set_reg_data`]. Panics if `en`/`clr` are forward
    /// references.
    pub fn reg(&mut self, d: NodeId, en: NodeId, clr: NodeId, init: bool) -> NodeId {
        let id = NodeId(self.ops.len() as u32);
        assert!(d.0 < id.0, "reg data fanin {d:?} is a forward reference (use set_reg_data)");
        assert!(en.0 < id.0, "reg enable fanin {en:?} is a forward reference");
        assert!(clr.0 < id.0, "reg clear fanin {clr:?} is a forward reference");
        self.ops.push(OP_REG);
        self.fanin.push([d.0, en.0, clr.0]);
        self.reg_inits.push((id.0, init));
        self.invalidate();
        id
    }

    /// Re-point an existing register's data fanin — the one sanctioned
    /// *edit* of a fanin record, which is how sequential feedback loops
    /// (`acc ← acc + x`) are built: create the register first (its `d`
    /// provisionally pointing anywhere valid, e.g. at itself via
    /// [`Netlist::reg_raw`]), build the logic that reads its output, then
    /// patch `d` to the loop's closing node. `d` may reference *any* node
    /// including later ones; the cycle is legal because it crosses the
    /// sequential cut. Panics if `r` is not a register or `d` is out of
    /// bounds.
    pub fn set_reg_data(&mut self, r: NodeId, d: NodeId) {
        let i = r.index();
        assert_eq!(self.ops[i], OP_REG, "set_reg_data on non-register node {i}");
        assert!((d.0 as usize) < self.ops.len(), "reg data fanin {d:?} out of bounds");
        self.fanin[i][0] = d.0;
        self.invalidate();
    }

    /// Append a register record with **no reference checks** (mirror of
    /// [`Netlist::push_raw`] for the sequential opcode): `d`, `en` and
    /// `clr` are taken verbatim, so forward references and dangling ids go
    /// through. Used by deserialization (which re-validates afterwards),
    /// lint fixtures, and as the seed node of a feedback loop
    /// ([`Netlist::set_reg_data`]).
    pub fn reg_raw(&mut self, d: u32, en: u32, clr: u32, init: bool) -> NodeId {
        let id = NodeId(self.ops.len() as u32);
        self.ops.push(OP_REG);
        self.fanin.push([d, en, clr]);
        self.reg_inits.push((id.0, init));
        self.invalidate();
        id
    }

    /// Init/reset value of register `id`. Panics if `id` is not a register.
    pub fn reg_init(&self, id: NodeId) -> bool {
        let at = self
            .reg_inits
            .binary_search_by_key(&id.0, |&(n, _)| n)
            .unwrap_or_else(|_| panic!("node {} is not a register", id.0));
        self.reg_inits[at].1
    }

    /// Number of register nodes. O(1).
    #[inline]
    pub fn num_regs(&self) -> usize {
        self.reg_inits.len()
    }

    /// Whether the netlist is sequential (contains at least one register).
    #[inline]
    pub fn is_sequential(&self) -> bool {
        !self.reg_inits.is_empty()
    }

    /// `(node id, init value)` per register, in creation order.
    #[inline]
    pub fn registers(&self) -> &[(u32, bool)] {
        &self.reg_inits
    }

    /// Append a raw `(opcode, fanin-record)` node with **no validity
    /// checks** — forward references, unknown opcodes and corrupt input
    /// ordinals all go through.
    ///
    /// This deliberately bypasses the invariants [`Netlist::gate`]
    /// enforces; it exists so lint tests and fuzzers can build malformed
    /// netlists that the checked constructors make unrepresentable. Never
    /// use it in synthesis code.
    pub fn push_raw(&mut self, op: u8, fanin: [u32; 3]) -> NodeId {
        let id = NodeId(self.ops.len() as u32);
        self.ops.push(op);
        self.fanin.push(fanin);
        if op <= 10 {
            self.n_gates += 1;
        }
        self.invalidate();
        id
    }

    // -- convenience constructors used throughout the synthesizer --------
    /// `a · b`.
    pub fn and2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::And2, &[a, b])
    }
    /// `a + b`.
    pub fn or2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Or2, &[a, b])
    }
    /// `!(a · b)`.
    pub fn nand2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Nand2, &[a, b])
    }
    /// `!(a + b)`.
    pub fn nor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Nor2, &[a, b])
    }
    /// `a ⊕ b`.
    pub fn xor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Xor2, &[a, b])
    }
    /// `!(a ⊕ b)`.
    pub fn xnor2(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.gate(CellKind::Xnor2, &[a, b])
    }
    /// `!a`.
    pub fn inv(&mut self, a: NodeId) -> NodeId {
        self.gate(CellKind::Inv, &[a])
    }
    /// Buffer (`a`).
    pub fn buf(&mut self, a: NodeId) -> NodeId {
        self.gate(CellKind::Buf, &[a])
    }
    /// `!((a · b) + c)`.
    pub fn aoi21(&mut self, a: NodeId, b: NodeId, c: NodeId) -> NodeId {
        self.gate(CellKind::Aoi21, &[a, b, c])
    }
    /// `!((a + b) · c)`.
    pub fn oai21(&mut self, a: NodeId, b: NodeId, c: NodeId) -> NodeId {
        self.gate(CellKind::Oai21, &[a, b, c])
    }
    /// Majority of three (the full-adder carry).
    pub fn maj3(&mut self, a: NodeId, b: NodeId, c: NodeId) -> NodeId {
        self.gate(CellKind::Maj3, &[a, b, c])
    }

    /// Register a named primary output.
    pub fn output(&mut self, name: impl Into<String>, id: NodeId) {
        let name_id = self.names.intern(&name.into());
        self.outputs.push((name_id, id));
        self.invalidate();
    }

    // -- flat accessors (the hot-loop API) -------------------------------
    /// Opcode per node: 0–10 = [`CellKind::opcode`], then [`OP_CONST0`],
    /// [`OP_CONST1`], [`OP_INPUT`], [`OP_REG`].
    #[inline]
    pub fn ops(&self) -> &[u8] {
        &self.ops
    }

    /// Inline fanin record per node — gate fanin node ids in slots
    /// `0..arity`; for inputs, slot 0 is the input ordinal.
    #[inline]
    pub fn fanin_records(&self) -> &[[u32; 3]] {
        &self.fanin
    }

    /// Arrival time (ns) per input ordinal (creation order).
    #[inline]
    pub fn input_arrivals(&self) -> &[f64] {
        &self.input_arrivals
    }

    /// Node id per input ordinal (creation order), as a borrowed slice.
    #[inline]
    pub fn input_ids(&self) -> &[NodeId] {
        &self.input_ids
    }

    /// Cell kind of node `i`, or `None` for inputs/constants.
    #[inline]
    pub fn kind_at(&self, i: usize) -> Option<CellKind> {
        let op = self.ops[i];
        if op <= 10 {
            Some(CellKind::ALL[op as usize])
        } else {
            None
        }
    }

    /// Fanin node ids of node `i` (`arity` entries; empty for
    /// inputs/constants).
    #[inline]
    #[allow(unsafe_code)] // sole unsafe in the library crate; see SAFETY below
    fn fanin_slice(&self, i: usize) -> &[NodeId] {
        let arity = match self.kind_at(i) {
            Some(kind) => kind.arity(),
            None => 0,
        };
        // SAFETY: `NodeId` is `#[repr(transparent)]` over `u32`, so a
        // `[u32; 3]` prefix of length `arity <= 3` reinterprets soundly;
        // the lifetime is tied to `&self`.
        unsafe { std::slice::from_raw_parts(self.fanin[i].as_ptr() as *const NodeId, arity) }
    }

    /// View of node `i` (internal, index-based).
    fn view(&self, i: usize) -> Node<'_> {
        match self.ops[i] {
            OP_INPUT => {
                let ordinal = self.fanin[i][0] as usize;
                Node::Input {
                    name: self.names.get(self.input_names[ordinal]),
                    arrival_ns: self.input_arrivals[ordinal],
                }
            }
            OP_CONST0 => Node::Const(false),
            OP_CONST1 => Node::Const(true),
            OP_REG => {
                let [d, en, clr] = self.fanin[i];
                // Tolerate a missing side entry (push_raw-built fixtures):
                // the view defaults to init=false rather than panicking.
                let init = self
                    .reg_inits
                    .binary_search_by_key(&(i as u32), |&(n, _)| n)
                    .map(|at| self.reg_inits[at].1)
                    .unwrap_or(false);
                Node::Reg { d: NodeId(d), en: NodeId(en), clr: NodeId(clr), init }
            }
            op => Node::Gate { kind: CellKind::ALL[op as usize], fanin: self.fanin_slice(i) },
        }
    }

    // -- view accessors ---------------------------------------------------
    /// One node by id, as a [`Node`] view.
    #[inline]
    pub fn node(&self, id: NodeId) -> Node<'_> {
        self.view(id.index())
    }

    /// Iterate [`Node`] views in topological order.
    pub fn iter(&self) -> NodeIter<'_> {
        NodeIter { nl: self, i: 0 }
    }

    /// Node count (inputs + constants + gates).
    #[inline]
    pub fn len(&self) -> usize {
        self.ops.len()
    }
    /// Whether the netlist has no nodes.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
    /// Named primary outputs in registration order, as `(name, id)` pairs.
    pub fn outputs(&self) -> OutputIter<'_> {
        OutputIter { nl: self, i: 0 }
    }
    /// Number of registered primary outputs.
    #[inline]
    pub fn num_outputs(&self) -> usize {
        self.outputs.len()
    }
    /// Primary-input count.
    #[inline]
    pub fn num_inputs(&self) -> usize {
        self.input_ids.len()
    }

    /// Number of gate instances (excludes inputs/constants). O(1): the
    /// count is maintained on append, not recomputed by a sweep.
    #[inline]
    pub fn num_gates(&self) -> usize {
        self.n_gates
    }

    /// Primary inputs in creation order.
    pub fn inputs(&self) -> Vec<NodeId> {
        self.input_ids.clone()
    }

    /// Map input name → node id.
    pub fn input_map(&self) -> HashMap<String, NodeId> {
        self.input_names
            .iter()
            .zip(&self.input_ids)
            .map(|(&name, &id)| (self.names.get(name).to_string(), id))
            .collect()
    }

    /// Total cell area in µm².
    pub fn area_um2(&self, lib: &CellLib) -> f64 {
        self.ops
            .iter()
            .map(|&op| {
                if op <= 10 {
                    lib.params(CellKind::ALL[op as usize]).area_um2
                } else {
                    0.0
                }
            })
            .sum()
    }

    /// The shared, lazily built [`Topology`] of this netlist. The first
    /// call after a structural edit rebuilds it (one O(nodes + edges)
    /// pass); subsequent calls clone the `Arc`.
    pub fn topology(&self) -> Arc<Topology> {
        let mut slot = match self.topo.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(t) = slot.as_ref() {
            return Arc::clone(t);
        }
        let t = Arc::new(self.build_topology());
        *slot = Some(Arc::clone(&t));
        t
    }

    fn build_topology(&self) -> Topology {
        let n = self.ops.len();
        // Gate-consumer degree per node (pre output bumps) drives the CSR.
        let mut fanout = vec![0u32; n];
        for i in 0..n {
            if let Some(kind) = self.kind_at(i) {
                let rec = self.fanin[i];
                for slot in rec.iter().take(kind.arity()) {
                    fanout[*slot as usize] += 1;
                }
            }
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + fanout[i];
        }
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        let mut consumers = vec![0u32; offsets[n] as usize];
        for i in 0..n {
            if let Some(kind) = self.kind_at(i) {
                let rec = self.fanin[i];
                for slot in rec.iter().take(kind.arity()) {
                    let driver = *slot as usize;
                    consumers[cursor[driver] as usize] = i as u32;
                    cursor[driver] += 1;
                }
            }
        }
        // Primary outputs count toward fanout but have no consumer row.
        for &(_, id) in &self.outputs {
            fanout[id.index()] += 1;
        }
        // Register pins likewise count toward fanout (a register is a real
        // consumer of its d/en/clr nets) but get no consumer rows: the CSR
        // walk is how arrival propagation travels, and a register is a
        // sequential cut — nothing combinational propagates through it.
        for i in 0..n {
            if self.ops[i] == OP_REG {
                for &f in &self.fanin[i] {
                    if (f as usize) < n {
                        fanout[f as usize] += 1;
                    }
                }
            }
        }
        let mut depths = vec![0u32; n];
        for i in 0..n {
            if let Some(kind) = self.kind_at(i) {
                let rec = self.fanin[i];
                let mut d = 0u32;
                for slot in rec.iter().take(kind.arity()) {
                    d = d.max(depths[*slot as usize]);
                }
                depths[i] = 1 + d;
            }
            // OP_REG keeps the default depth 0: registers restart the
            // depth count exactly as they restart STA arrivals.
        }
        // Sequential endpoints: a path ends at a primary output or at a
        // register's data pin, so the reported depth is the max over both —
        // the deepest *combinational segment*, not the input→output depth
        // (which is 0 for a fully registered output).
        let mut depth =
            self.outputs.iter().map(|&(_, id)| depths[id.index()]).max().unwrap_or(0);
        for i in 0..n {
            if self.ops[i] == OP_REG {
                let d = self.fanin[i][0] as usize;
                if d < n {
                    depth = depth.max(depths[d]);
                }
            }
        }
        Topology { fanout, offsets, consumers, depths, depth }
    }

    /// Fanout count per node (number of gate inputs each node drives;
    /// primary outputs add `1` each). Served from the cached topology.
    pub fn fanout_counts(&self) -> Vec<u32> {
        self.topology().fanout_counts().to_vec()
    }

    /// Capacitive load per node in unit loads (sum of driven input caps;
    /// primary outputs add `lib.output_load`). One linear pass over the
    /// flat fanin records; the accumulation order is fixed (gate
    /// contributions in topological order, then outputs in registration
    /// order) so repeated calls are bit-identical.
    pub fn loads(&self, lib: &CellLib) -> Vec<f64> {
        let mut load = vec![0.0f64; self.ops.len()];
        for i in 0..self.ops.len() {
            if let Some(kind) = self.kind_at(i) {
                let cin = lib.params(kind).input_cap;
                let rec = self.fanin[i];
                for slot in rec.iter().take(kind.arity()) {
                    load[*slot as usize] += cin;
                }
            }
        }
        for &(_, id) in &self.outputs {
            load[id.index()] += lib.output_load;
        }
        load
    }

    /// Logic depth (gate count) per node; inputs/constants are depth 0.
    /// Served from the cached topology.
    pub fn depths(&self) -> Vec<u32> {
        self.topology().depths().to_vec()
    }

    /// Maximum logic depth over primary outputs. Served from the cached
    /// topology.
    pub fn depth(&self) -> u32 {
        self.topology().depth()
    }

    /// Histogram of cell kinds, for reports.
    pub fn cell_histogram(&self) -> HashMap<CellKind, usize> {
        let mut h = HashMap::new();
        for &op in &self.ops {
            if op <= 10 {
                *h.entry(CellKind::ALL[op as usize]).or_insert(0) += 1;
            }
        }
        h
    }

    /// Structural validation: opcodes, input ordinals and topological
    /// order. Returns a human-readable error description on failure.
    pub fn validate(&self) -> Result<(), String> {
        for i in 0..self.ops.len() {
            let op = self.ops[i];
            if let Some(kind) = self.kind_at(i) {
                let rec = self.fanin[i];
                for slot in rec.iter().take(kind.arity()) {
                    if *slot as usize >= i {
                        return Err(format!("node {i}: forward/self reference to {slot}"));
                    }
                }
            } else if op == OP_INPUT {
                let ordinal = self.fanin[i][0] as usize;
                if ordinal >= self.input_ids.len() || self.input_ids[ordinal].index() != i {
                    return Err(format!("node {i}: corrupt input ordinal {ordinal}"));
                }
            } else if op == OP_REG {
                // The data pin may point anywhere in the netlist (sequential
                // feedback crosses the cut); control must be strictly
                // earlier — a same-cycle loop through en/clr never settles.
                let [d, en, clr] = self.fanin[i];
                if d as usize >= self.ops.len() {
                    return Err(format!("node {i}: register data fanin {d} dangles"));
                }
                for (pin, f) in [("enable", en), ("clear", clr)] {
                    if f as usize >= i {
                        return Err(format!(
                            "node {i}: register {pin} fanin {f} is not strictly earlier"
                        ));
                    }
                }
            } else if op != OP_CONST0 && op != OP_CONST1 {
                return Err(format!("node {i}: unknown opcode {op}"));
            }
        }
        for (name, id) in self.outputs() {
            if id.index() >= self.ops.len() {
                return Err(format!("output {name}: dangling node {}", id.0));
            }
        }
        Ok(())
    }
}

/// Iterator of [`Node`] views in topological order — see [`Netlist::iter`].
#[derive(Clone)]
pub struct NodeIter<'a> {
    nl: &'a Netlist,
    i: usize,
}

impl<'a> Iterator for NodeIter<'a> {
    type Item = Node<'a>;

    fn next(&mut self) -> Option<Node<'a>> {
        if self.i >= self.nl.ops.len() {
            return None;
        }
        let node = self.nl.view(self.i);
        self.i += 1;
        Some(node)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.nl.ops.len() - self.i;
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for NodeIter<'_> {}

/// Iterator over named primary outputs — see [`Netlist::outputs`].
#[derive(Clone)]
pub struct OutputIter<'a> {
    nl: &'a Netlist,
    i: usize,
}

impl<'a> Iterator for OutputIter<'a> {
    type Item = (&'a str, NodeId);

    fn next(&mut self) -> Option<(&'a str, NodeId)> {
        let &(name, id) = self.nl.outputs.get(self.i)?;
        self.i += 1;
        Some((self.nl.names.get(name), id))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = self.nl.outputs.len() - self.i;
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for OutputIter<'_> {}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_chain(n: usize) -> Netlist {
        let mut nl = Netlist::new("xorchain");
        let mut prev = nl.input("i0");
        for k in 1..=n {
            let i = nl.input(format!("i{k}"));
            prev = nl.xor2(prev, i);
        }
        nl.output("o", prev);
        nl
    }

    #[test]
    fn reserve_prevents_growth_during_build() {
        let mut nl = Netlist::new("reserved");
        let a = nl.input("a");
        let b = nl.input("b");
        nl.reserve(100);
        let cap = nl.capacity();
        assert!(cap >= 102);
        let mut prev = nl.and2(a, b);
        for _ in 0..99 {
            prev = nl.xor2(prev, a);
        }
        assert_eq!(nl.capacity(), cap, "no reallocation within the reserved budget");
        assert_eq!(nl.len(), 102);
    }

    #[test]
    fn builds_and_validates() {
        let nl = xor_chain(7);
        nl.validate().unwrap();
        assert_eq!(nl.num_inputs(), 8);
        assert_eq!(nl.num_gates(), 7);
        assert_eq!(nl.depth(), 7);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut nl = Netlist::new("bad");
        let a = nl.input("a");
        nl.gate(CellKind::Xor2, &[a]);
    }

    #[test]
    fn fanout_and_load_accounting() {
        let mut nl = Netlist::new("fan");
        let a = nl.input("a");
        let b = nl.input("b");
        let x = nl.xor2(a, b);
        let y = nl.and2(x, a);
        let z = nl.or2(x, y);
        nl.output("z", z);
        let fo = nl.fanout_counts();
        assert_eq!(fo[x.index()], 2); // x drives y and z
        assert_eq!(fo[a.index()], 2); // a drives x and y
        let lib = CellLib::nangate45();
        let loads = nl.loads(&lib);
        let expect = lib.params(CellKind::And2).input_cap + lib.params(CellKind::Or2).input_cap;
        assert!((loads[x.index()] - expect).abs() < 1e-12);
        // output z carries the default output load
        assert!((loads[z.index()] - lib.output_load).abs() < 1e-12);
    }

    #[test]
    fn area_sums_cells_only() {
        let nl = xor_chain(3);
        let lib = CellLib::nangate45();
        let expect = 3.0 * lib.params(CellKind::Xor2).area_um2;
        assert!((nl.area_um2(&lib) - expect).abs() < 1e-9);
    }

    #[test]
    fn node_views_roundtrip_flat_storage() {
        let mut nl = Netlist::new("views");
        let a = nl.input_at("alpha", 0.25);
        let b = nl.input("beta");
        let k = nl.constant(true);
        let g = nl.aoi21(a, b, k);
        nl.output("g", g);
        match nl.node(a) {
            Node::Input { name, arrival_ns } => {
                assert_eq!(name, "alpha");
                assert_eq!(arrival_ns, 0.25);
            }
            other => panic!("not an input view: {other:?}"),
        }
        match nl.node(k) {
            Node::Const(v) => assert!(v),
            other => panic!("not a const view: {other:?}"),
        }
        match nl.node(g) {
            Node::Gate { kind, fanin } => {
                assert_eq!(kind, CellKind::Aoi21);
                assert_eq!(fanin, &[a, b, k]);
            }
            other => panic!("not a gate view: {other:?}"),
        }
        assert_eq!(nl.iter().count(), nl.len());
        let outs: Vec<(&str, NodeId)> = nl.outputs().collect();
        assert_eq!(outs, vec![("g", g)]);
        assert_eq!(nl.outputs().len(), 1);
    }

    #[test]
    fn topology_invalidates_on_append_not_on_arrival_edit() {
        let mut nl = xor_chain(4);
        let t0 = nl.topology();
        // Arrival edits keep the cached topology (same Arc).
        let inputs = nl.inputs();
        nl.set_input_arrival(inputs[0], 0.5);
        let t1 = nl.topology();
        assert!(Arc::ptr_eq(&t0, &t1), "arrival edit must not invalidate topology");
        assert_eq!(t1.depth(), 4);
        // Structural edits rebuild it.
        let extra = nl.inv(inputs[0]);
        nl.output("x", extra);
        let t2 = nl.topology();
        assert!(!Arc::ptr_eq(&t1, &t2), "append must invalidate topology");
        assert_eq!(t2.fanout_counts()[inputs[0].index()], 2); // xor + inv
        assert_eq!(t2.depths()[extra.index()], 1);
    }

    #[test]
    fn interned_names_survive_growth() {
        let mut nl = Netlist::new("names");
        let ids: Vec<NodeId> =
            (0..100).map(|k| nl.input(format!("in_{k}"))).collect();
        let g = nl.and2(ids[0], ids[99]);
        nl.output("the_output", g);
        let im = nl.input_map();
        assert_eq!(im.len(), 100);
        assert_eq!(im["in_42"], ids[42]);
        match nl.node(ids[7]) {
            Node::Input { name, .. } => assert_eq!(name, "in_7"),
            other => panic!("{other:?}"),
        }
        assert_eq!(nl.outputs().next().unwrap().0, "the_output");
    }

    #[test]
    fn csr_consumers_match_fanin_records() {
        let mut nl = Netlist::new("csr");
        let a = nl.input("a");
        let b = nl.input("b");
        let x = nl.xor2(a, b);
        let y = nl.and2(x, x); // duplicate sampling of one driver
        let z = nl.or2(x, y);
        nl.output("z", z);
        let t = nl.topology();
        assert_eq!(t.consumers(x.index()), &[y.0, y.0, z.0]);
        assert_eq!(t.consumers(a.index()), &[x.0]);
        assert_eq!(t.fanout_counts()[x.index()], 3);
        assert_eq!(t.fanout_counts()[z.index()], 1); // the output
    }

    #[test]
    fn registers_are_topology_cut_points() {
        let mut nl = Netlist::new("seq");
        let a = nl.input("a");
        let b = nl.input("b");
        let en = nl.constant(true);
        let clr = nl.constant(false);
        let x = nl.xor2(a, b); // depth 1
        let r = nl.reg(x, en, clr, false);
        let y = nl.and2(r, a); // depth restarts after the register
        nl.output("y", y);
        nl.validate().unwrap();
        assert_eq!(nl.num_regs(), 1);
        assert!(nl.is_sequential());
        assert!(!nl.reg_init(r));
        let t = nl.topology();
        assert_eq!(t.depths()[x.index()], 1);
        assert_eq!(t.depths()[r.index()], 0, "register cuts the depth count");
        assert_eq!(t.depths()[y.index()], 1);
        // Deepest combinational segment: the xor feeding the register's d
        // pin ties the and2 at the output.
        assert_eq!(t.depth(), 1);
        // The register is a fanout consumer of its pins but has no CSR row
        // (nothing combinational propagates through the cut).
        assert_eq!(t.fanout_counts()[x.index()], 1);
        assert!(t.consumers(x.index()).is_empty());
        match nl.node(r) {
            Node::Reg { d, en: e, clr: c, init } => {
                assert_eq!((d, e, c, init), (x, en, clr, false));
            }
            other => panic!("not a register view: {other:?}"),
        }
    }

    #[test]
    fn feedback_register_patches_and_validates() {
        // Toggle flip-flop: q feeds an inverter that feeds q back.
        let mut nl = Netlist::new("tff");
        let en = nl.input("en");
        let clr = nl.constant(false);
        let q = nl.reg_raw(0, en.0, clr.0, false); // provisional d
        let nq = nl.inv(q);
        nl.set_reg_data(q, nq);
        nl.output("q", q);
        nl.validate().unwrap();
        match nl.node(q) {
            Node::Reg { d, .. } => assert_eq!(d, nq, "patched data pin"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn validate_rejects_forward_register_control() {
        let mut nl = Netlist::new("badctl");
        let d = nl.input("d");
        // enable points at the register itself: a same-cycle control loop.
        let r = nl.reg_raw(d.0, 1, 1, false);
        nl.output("q", r);
        assert!(nl.validate().unwrap_err().contains("enable"));
    }
}
