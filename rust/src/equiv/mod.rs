//! Functional equivalence checking (the paper's ABC step, §5.1).
//!
//! Combinational designs are verified against the integer golden model:
//! exhaustively for small operand widths (formally complete), and with
//! structured + random vectors beyond that (corner patterns — all-zeros,
//! all-ones, walking ones, alternating masks — plus packed random lanes).
//! The PJRT-backed variant (netlist-eval artifact executed from the Rust
//! request path) lives in [`crate::runtime`] and is exercised by the
//! examples.

use crate::multiplier::Design;
use crate::sim::{lane_value, CompiledNetlist};
use crate::Result;

/// Outcome of an equivalence run.
#[derive(Debug, Clone)]
pub struct EquivReport {
    /// Whether every checked vector matched the golden model.
    pub passed: bool,
    /// Vectors simulated.
    pub vectors: usize,
    /// Whether the whole input space was covered.
    pub exhaustive: bool,
    /// First failing `(a, b, c, got, want)` if any.
    pub counterexample: Option<(u128, u128, u128, u128, u128)>,
}

/// Verify a multiplier/MAC design. Exhaustive when the total input space
/// `2^(bits)` is at most `2^20`; sampled otherwise (`vectors` lanes).
pub fn check_multiplier(design: &Design) -> Result<EquivReport> {
    check_multiplier_with(design, 1 << 14)
}

/// As [`check_multiplier`] with an explicit sampled-vector budget.
///
/// Operand widths come from the design itself (`a`/`b`/`c` pin vectors),
/// so rectangular formats are swept over their own per-operand ranges, and
/// the golden model ([`Design::expected`]) applies the design's signedness.
pub fn check_multiplier_with(design: &Design, budget: usize) -> Result<EquivReport> {
    let total_bits = design.a.len() + design.b.len() + design.c.len();
    if total_bits <= 20 {
        exhaustive(design)
    } else {
        sampled(design, budget)
    }
}

fn run_batch(
    design: &Design,
    comp: &CompiledNetlist,
    buf: &mut Vec<u64>,
    batch: &[(u128, u128, u128)],
) -> Option<(u128, u128, u128, u128, u128)> {
    // Pack operands straight into lane words (inputs are created in
    // a-then-b-then-c order by the generators) — no per-vector Vec<bool>
    // round-trip, no buffer copy. This is the §Perf-optimized form; see
    // EXPERIMENTS.md.
    let a_bits = design.a.len();
    let b_bits = design.b.len();
    let c_bits = design.c.len();
    let mut words = vec![0u64; a_bits + b_bits + c_bits];
    for (lane, (a, b, c)) in batch.iter().enumerate() {
        let bit = 1u64 << lane;
        for k in 0..a_bits {
            if a >> k & 1 == 1 {
                words[k] |= bit;
            }
        }
        for k in 0..b_bits {
            if b >> k & 1 == 1 {
                words[a_bits + k] |= bit;
            }
        }
        for k in 0..c_bits {
            if c >> k & 1 == 1 {
                words[a_bits + b_bits + k] |= bit;
            }
        }
    }
    comp.run_into(buf, &words);
    for (lane, (a, b, c)) in batch.iter().enumerate() {
        let got = lane_value(buf, &design.product, lane as u32);
        let want = design.expected(*a, *b, *c);
        if got != want {
            return Some((*a, *b, *c, got, want));
        }
    }
    None
}

fn exhaustive(design: &Design) -> Result<EquivReport> {
    let c_bits = design.c.len() as u32;
    let comp = CompiledNetlist::compile(&design.netlist);
    let mut buf: Vec<u64> = Vec::new();
    let mut batch: Vec<(u128, u128, u128)> = Vec::with_capacity(64);
    let mut vectors = 0usize;
    let na = 1u128 << design.a.len() as u32;
    let nb = 1u128 << design.b.len() as u32;
    let nc = 1u128 << c_bits;
    let mut a = 0u128;
    while a < na {
        let mut b = 0u128;
        while b < nb {
            let mut c = 0u128;
            while c < nc {
                batch.push((a, b, c));
                vectors += 1;
                if batch.len() == 64 {
                    if let Some(cex) = run_batch(design, &comp, &mut buf, &batch) {
                        return Ok(EquivReport {
                            passed: false,
                            vectors,
                            exhaustive: true,
                            counterexample: Some(cex),
                        });
                    }
                    batch.clear();
                }
                c += 1;
            }
            b += 1;
        }
        a += 1;
    }
    if !batch.is_empty() {
        if let Some(cex) = run_batch(design, &comp, &mut buf, &batch) {
            return Ok(EquivReport {
                passed: false,
                vectors,
                exhaustive: true,
                counterexample: Some(cex),
            });
        }
    }
    Ok(EquivReport { passed: true, vectors, exhaustive: true, counterexample: None })
}

/// Boundary operands and walking ones for one operand width.
fn corner_list(bits: usize) -> Vec<u128> {
    let mask = (1u128 << bits) - 1;
    let mut corners: Vec<u128> = vec![0, 1, mask, mask.saturating_sub(1), mask >> 1, (mask >> 1) + 1];
    for k in 0..bits {
        corners.push(1u128 << k);
        corners.push(mask ^ (1u128 << k));
    }
    corners.sort();
    corners.dedup();
    corners.retain(|&c| c <= mask);
    corners
}

fn sampled(design: &Design, budget: usize) -> Result<EquivReport> {
    let a_bits = design.a.len();
    let b_bits = design.b.len();
    let c_bits = design.c.len();
    let amask = (1u128 << a_bits) - 1;
    let bmask = (1u128 << b_bits) - 1;
    let cmask = if c_bits == 0 { 0 } else { (1u128 << c_bits) - 1 };
    let mut rng = crate::util::Rng::seed_from_u64(0xE9E9);
    let comp = CompiledNetlist::compile(&design.netlist);
    let mut buf: Vec<u64> = Vec::new();
    let mut vectors = 0usize;

    // Corner vectors: boundary operands and walking ones, per operand.
    let corners_a = corner_list(a_bits);
    let corners_b = corner_list(b_bits);
    let mut batch: Vec<(u128, u128, u128)> = Vec::with_capacity(64);
    let flush = |batch: &mut Vec<(u128, u128, u128)>,
                 buf: &mut Vec<u64>,
                 vectors: &mut usize|
     -> Option<(u128, u128, u128, u128, u128)> {
        *vectors += batch.len();
        let r = run_batch(design, &comp, buf, batch);
        batch.clear();
        r
    };
    for &a in &corners_a {
        for &b in &corners_b {
            let c = (a.wrapping_mul(31) ^ b) & cmask;
            batch.push((a, b, c));
            if batch.len() == 64 {
                if let Some(cex) = flush(&mut batch, &mut buf, &mut vectors) {
                    return Ok(EquivReport {
                        passed: false,
                        vectors,
                        exhaustive: false,
                        counterexample: Some(cex),
                    });
                }
            }
        }
    }
    // Random lanes.
    while vectors < budget {
        while batch.len() < 64 {
            let a = (u128::from(rng.next_u64()) << 64 | u128::from(rng.next_u64())) & amask;
            let b = (u128::from(rng.next_u64()) << 64 | u128::from(rng.next_u64())) & bmask;
            let c = (u128::from(rng.next_u64()) << 64 | u128::from(rng.next_u64())) & cmask;
            batch.push((a, b, c));
        }
        if let Some(cex) = flush(&mut batch, &mut buf, &mut vectors) {
            return Ok(EquivReport {
                passed: false,
                vectors,
                exhaustive: false,
                counterexample: Some(cex),
            });
        }
    }
    Ok(EquivReport { passed: true, vectors, exhaustive: false, counterexample: None })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multiplier::{MultiplierSpec, OperandFormat};

    #[test]
    fn passes_signed_rectangular_mac_exhaustive() {
        let d = MultiplierSpec::new_fmt(OperandFormat::signed_rect(3, 4))
            .fused_mac(true)
            .build()
            .unwrap();
        let r = check_multiplier(&d).unwrap();
        assert!(r.passed && r.exhaustive);
        assert_eq!(r.vectors, 1 << 14); // 3 + 4 + 7 bits
    }

    #[test]
    fn sampled_mode_per_operand_masks() {
        // 16×8 unsigned: 24 operand bits force the sampled path; per-operand
        // masks must keep b inside its own 8-bit range.
        let d = MultiplierSpec::new_fmt(OperandFormat::rect(16, 8)).build().unwrap();
        let r = check_multiplier_with(&d, 1024).unwrap();
        assert!(r.passed && !r.exhaustive);
    }

    #[test]
    fn passes_correct_small_multiplier() {
        let d = MultiplierSpec::new(4).build().unwrap();
        let r = check_multiplier(&d).unwrap();
        assert!(r.passed);
        assert!(r.exhaustive);
        assert_eq!(r.vectors, 256);
    }

    #[test]
    fn passes_correct_mac_exhaustive() {
        let d = MultiplierSpec::new(3).fused_mac(true).build().unwrap();
        let r = check_multiplier(&d).unwrap();
        assert!(r.passed && r.exhaustive);
        assert_eq!(r.vectors, 1 << 12); // 3+3+6 bits
    }

    #[test]
    fn sampled_mode_for_16bit() {
        let d = MultiplierSpec::new(16).build().unwrap();
        let r = check_multiplier_with(&d, 2048).unwrap();
        assert!(r.passed);
        assert!(!r.exhaustive);
        assert!(r.vectors >= 2048);
    }

    #[test]
    fn detects_injected_fault() {
        // Break the design by remapping one product bit to another node.
        let mut d = MultiplierSpec::new(4).build().unwrap();
        d.product[3] = d.product[4];
        let r = check_multiplier(&d).unwrap();
        assert!(!r.passed);
        let (a, b, c, got, want) = r.counterexample.unwrap();
        assert_eq!(got, {
            let _ = (a, b, c);
            got
        });
        assert_ne!(got, want);
    }
}
