//! Wire-level types of the design-compilation protocol: command parsing
//! and response construction. `PROTOCOL.md` at the repository root is the
//! normative description; every JSON example there is replayed verbatim by
//! `rust/tests/server.rs`.

use crate::analysis::AnalysisReport;
use crate::api::{persist, CompileSource, DesignArtifact, DesignRequest};
use crate::coordinator::SweepConfig;
use crate::lint::LintReport;
use crate::ppg::Signedness;
use crate::util::Json;
use crate::Result;
use anyhow::{anyhow, bail};

/// One parsed wire command.
#[derive(Debug)]
pub enum Command {
    /// Compile a single [`DesignRequest`].
    Compile(DesignRequest),
    /// Compile many requests on the engine's thread pool.
    Batch(Vec<DesignRequest>),
    /// Compile (or fetch) a request and return its static-analysis report
    /// ([`crate::lint`]) instead of the STA summary.
    Lint(DesignRequest),
    /// Compile (or fetch) a request and return its abstract-interpretation
    /// report ([`crate::analysis`]): proven constants, static activity,
    /// word-level intervals and the UFO4xx diagnostics.
    Analyze(DesignRequest),
    /// Run a (method × width × strategy × signedness) DSE sweep through
    /// the server's engine and cache.
    Sweep(Box<SweepConfig>),
    /// Report cache / timing / queue statistics.
    Stats,
    /// Report the observability snapshot ([`crate::server::metrics`]):
    /// cache tiers, per-class queue depths, per-command latency
    /// histograms, uptime and lifetime totals.
    Metrics,
    /// Stop serving this connection after responding.
    Shutdown,
}

impl Command {
    /// Stable wire key of the command (the `metrics` latency-histogram
    /// keys).
    pub fn key(&self) -> &'static str {
        match self {
            Command::Compile(_) => "compile",
            Command::Batch(_) => "batch",
            Command::Lint(_) => "lint",
            Command::Analyze(_) => "analyze",
            Command::Sweep(_) => "sweep",
            Command::Stats => "stats",
            Command::Metrics => "metrics",
            Command::Shutdown => "shutdown",
        }
    }
}

/// One parsed request: the command plus its transport options (today just
/// the opt-in `stream` flag for progress frames).
#[derive(Debug)]
pub struct Request {
    /// The wire command.
    pub cmd: Command,
    /// `"stream": true` — emit `{"event":"progress",…}` frames before the
    /// final envelope. Ignored by commands with nothing to stream.
    pub stream: bool,
}

/// Parse one request line: returns the echoed `id` (JSON `null` when the
/// line carries none or cannot be parsed) and the request or a protocol
/// error.
pub fn parse_line(line: &str) -> (Json, Result<Request>) {
    let doc = match Json::parse(line) {
        Ok(doc) => doc,
        Err(e) => return (Json::Null, Err(anyhow!("request is not valid JSON: {e}"))),
    };
    let id = doc.get("id").cloned().unwrap_or(Json::Null);
    let req = parse_command(&doc).and_then(|cmd| {
        let stream = match doc.get("stream") {
            None => false,
            Some(Json::Bool(b)) => *b,
            Some(_) => bail!("'stream' must be a bool"),
        };
        Ok(Request { cmd, stream })
    });
    (id, req)
}

fn parse_command(doc: &Json) -> Result<Command> {
    let cmd = doc
        .get("cmd")
        .and_then(|c| c.as_str())
        .ok_or_else(|| anyhow!("missing or non-string field 'cmd'"))?;
    match cmd {
        "compile" => {
            let req = doc
                .get("request")
                .ok_or_else(|| anyhow!("compile: missing field 'request'"))?;
            Ok(Command::Compile(DesignRequest::from_json(req)?))
        }
        "batch" => {
            let rows = doc
                .get("requests")
                .and_then(|r| r.as_arr())
                .ok_or_else(|| anyhow!("batch: field 'requests' must be an array"))?;
            if rows.is_empty() {
                bail!("batch: 'requests' must not be empty");
            }
            rows.iter().map(DesignRequest::from_json).collect::<Result<Vec<_>>>().map(Command::Batch)
        }
        "lint" => {
            let req =
                doc.get("request").ok_or_else(|| anyhow!("lint: missing field 'request'"))?;
            Ok(Command::Lint(DesignRequest::from_json(req)?))
        }
        "analyze" => {
            let req =
                doc.get("request").ok_or_else(|| anyhow!("analyze: missing field 'request'"))?;
            Ok(Command::Analyze(DesignRequest::from_json(req)?))
        }
        "sweep" => Ok(Command::Sweep(Box::new(sweep_config(doc)?))),
        "stats" => Ok(Command::Stats),
        "metrics" => Ok(Command::Metrics),
        "shutdown" => Ok(Command::Shutdown),
        other => {
            bail!(
                "unknown cmd '{other}' (valid: analyze, batch, compile, lint, metrics, shutdown, stats, sweep)"
            )
        }
    }
}

/// Build a [`SweepConfig`] from the optional axis fields of a `sweep`
/// command (defaults from [`SweepConfig::default`] for omitted axes).
/// Method/strategy/signedness names use the same strict parsers as the CLI
/// flags — unknown values are errors listing the valid choices.
fn sweep_config(doc: &Json) -> Result<SweepConfig> {
    let mut cfg = SweepConfig::default();
    if let Some(ws) = doc.get("widths") {
        let ws = ws.as_arr().ok_or_else(|| anyhow!("sweep: 'widths' must be an array"))?;
        cfg.widths = ws
            .iter()
            .map(|w| match w.as_f64() {
                Some(x) if x.fract() == 0.0 && (1.0..=128.0).contains(&x) => Ok(x as usize),
                _ => bail!("sweep: widths must be integers in 1..=128"),
            })
            .collect::<Result<_>>()?;
    }
    if let Some(ms) = doc.get("methods") {
        let ms = ms.as_arr().ok_or_else(|| anyhow!("sweep: 'methods' must be an array"))?;
        cfg.methods = ms
            .iter()
            .map(|m| {
                m.as_str()
                    .ok_or_else(|| anyhow!("sweep: methods must be strings"))?
                    .parse()
            })
            .collect::<Result<_>>()?;
    }
    if let Some(ss) = doc.get("strategies") {
        let ss = ss.as_arr().ok_or_else(|| anyhow!("sweep: 'strategies' must be an array"))?;
        cfg.strategies = ss
            .iter()
            .map(|s| {
                s.as_str()
                    .ok_or_else(|| anyhow!("sweep: strategies must be strings"))?
                    .parse()
            })
            .collect::<Result<_>>()?;
    }
    if let Some(sg) = doc.get("signedness") {
        let sg = sg.as_arr().ok_or_else(|| anyhow!("sweep: 'signedness' must be an array"))?;
        cfg.signedness = sg
            .iter()
            .map(|s| match s.as_str() {
                Some("unsigned") => Ok(Signedness::Unsigned),
                Some("signed") => Ok(Signedness::Signed),
                _ => bail!("sweep: unknown signedness (valid: signed, unsigned)"),
            })
            .collect::<Result<_>>()?;
    }
    if let Some(mac) = doc.get("mac") {
        cfg.mac = mac.as_bool().ok_or_else(|| anyhow!("sweep: 'mac' must be a bool"))?;
    }
    Ok(cfg)
}

// -------------------------------------------------------------------
// Responses.
// -------------------------------------------------------------------

/// Success envelope: `{"id":…,"ok":true,"result":…}`.
pub fn envelope_ok(id: &Json, result: Json) -> Json {
    Json::obj(vec![("id", id.clone()), ("ok", Json::Bool(true)), ("result", result)])
}

/// Error envelope: `{"error":…,"id":…,"ok":false}`.
pub fn envelope_err(id: &Json, error: &str) -> Json {
    Json::obj(vec![
        ("id", id.clone()),
        ("ok", Json::Bool(false)),
        ("error", Json::str(error)),
    ])
}

/// Streamed progress frame:
/// `{"done":k,"event":"progress","id":…,<payload>,"total":n}`.
///
/// Frames never carry an `ok` key, so clients can always distinguish a
/// frame from the final envelope. The payload key is per command: `point`
/// (a sweep design point, `null` for a failed compile), `row` (a batch
/// row), or `source` (a streamed single compile).
pub fn progress_frame(id: &Json, done: usize, total: usize, payload: (&str, Json)) -> Json {
    Json::obj(vec![
        ("done", Json::num(done as f64)),
        ("event", Json::str("progress")),
        ("id", id.clone()),
        payload,
        ("total", Json::num(total as f64)),
    ])
}

/// Compile-result summary: fingerprint, which tier/path produced the
/// artifact, the canonical request, the STA headline numbers, the clocked
/// module report when the request was a module, and the verification
/// flags.
pub fn artifact_summary(art: &DesignArtifact, source: CompileSource) -> Json {
    let sta = Json::obj(vec![
        ("critical_delay_ns", Json::num(art.sta.critical_delay_ns)),
        ("area_um2", Json::num(art.sta.area_um2)),
        ("power_mw", Json::num(art.sta.power_mw)),
        ("num_gates", Json::num(art.sta.num_gates as f64)),
        ("depth", Json::num(art.sta.depth as f64)),
    ]);
    Json::obj(vec![
        ("fingerprint", Json::str(art.fingerprint.to_string())),
        ("source", Json::str(source.key())),
        ("canonical", art.request.to_json()),
        ("sta", sta),
        (
            "module",
            match art.module_report() {
                None => Json::Null,
                Some(r) => persist::report_to_json(r),
            },
        ),
        // Pipeline metadata of registered designs (`null` for purely
        // combinational artifacts): stage count, cycle latency, and the
        // number of registers in the emitted netlist.
        (
            "pipeline",
            match art.pipeline() {
                None => Json::Null,
                Some(p) => Json::obj(vec![
                    ("stages", Json::num(p.stages as f64)),
                    ("latency", Json::num(p.latency() as f64)),
                    ("registers", Json::num(art.netlist().num_regs() as f64)),
                ]),
            },
        ),
        ("verified", persist::opt_bool(art.verified)),
        ("pjrt_verified", persist::opt_bool(art.pjrt_verified)),
    ])
}

/// `lint`-command result: the report summary (clean flag, per-severity
/// counts, the diagnostics themselves) plus the fingerprint and cache
/// provenance of the artifact it describes.
pub fn lint_summary(report: &LintReport, art: &DesignArtifact, source: CompileSource) -> Json {
    let Json::Obj(mut m) = report.summary_json() else {
        unreachable!("lint summary must be an object");
    };
    m.insert("fingerprint".to_string(), Json::str(art.fingerprint.to_string()));
    m.insert("source".to_string(), Json::str(source.key()));
    Json::Obj(m)
}

/// `analyze`-command result: the abstract-interpretation summary (clean
/// flag, per-severity counts, proven-constant tally, mean activity, output
/// group intervals, the diagnostics themselves) plus the fingerprint and
/// cache provenance of the artifact it describes.
pub fn analysis_summary(
    report: &AnalysisReport,
    art: &DesignArtifact,
    source: CompileSource,
) -> Json {
    let Json::Obj(mut m) = report.summary_json() else {
        unreachable!("analysis summary must be an object");
    };
    m.insert("fingerprint".to_string(), Json::str(art.fingerprint.to_string()));
    m.insert("source".to_string(), Json::str(source.key()));
    Json::Obj(m)
}
