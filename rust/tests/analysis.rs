//! Soundness harness for the abstract-interpretation subsystem: on every
//! tier-1 design family × operand format × pipelined variant, concrete
//! 64-lane simulation values must lie inside the proven abstract values —
//! ternary constants, output-group intervals, and probability bounds.
//! Plus: worker-count independence of the full report, exact-code UFO4xx
//! fixtures, the UFO301 regression through the ternary domain, and the
//! static-vs-measured switching-activity cross-checks of both the
//! combinational and the clocked toggle sweeps.
//!
//! Every randomized test derives its RNG from an explicit seed and
//! includes that seed in the panic message.

use ufo_mac::analysis::{
    analyze_design, analyze_netlist, static_activity, AnalysisOptions, AnalysisOutcome,
};
use ufo_mac::api::{tier1_requests, EngineConfig, SynthEngine};
use ufo_mac::ir::{Netlist, NodeId, OP_CONST0, OP_CONST1, OP_INPUT};
use ufo_mac::lint::{lint_netlist, LintOptions, Locus, Severity};
use ufo_mac::multiplier::MultiplierSpec;
use ufo_mac::sim::{lane_value, toggle_activity, ClockedSim, Simulator};
use ufo_mac::util::Rng;

fn codes(report: &ufo_mac::analysis::AnalysisReport) -> Vec<&'static str> {
    report.diagnostics.iter().map(|d| d.code).collect()
}

/// Assert one packed node view (64 lanes) lies inside the abstract
/// values: a node proven 0 must read all-zeros, a node proven 1 all-ones,
/// and every output-group word must fall inside its proven interval.
fn assert_contained(nl: &Netlist, out: &AnalysisOutcome, view: &[u64], ctx: &str) {
    for i in 0..nl.len() {
        match out.ternary[i] {
            ufo_mac::analysis::Tern::Zero => {
                assert_eq!(view[i], 0, "{ctx}: node {i} proven 0 but simulates {:#x}", view[i]);
            }
            ufo_mac::analysis::Tern::One => {
                assert_eq!(
                    view[i],
                    u64::MAX,
                    "{ctx}: node {i} proven 1 but simulates {:#x}",
                    view[i]
                );
            }
            ufo_mac::analysis::Tern::Unknown => {}
        }
    }
    for g in &out.groups {
        let Some((lo, hi)) = ufo_mac::analysis::group_interval(g, &out.ternary) else {
            continue;
        };
        let bits: Vec<NodeId> = g.bits.iter().map(|&b| NodeId(b)).collect();
        for lane in 0..64 {
            let v = lane_value(view, &bits, lane);
            assert!(
                (lo..=hi).contains(&v),
                "{ctx}: group '{}' lane {lane} value {v} outside proven [{lo}, {hi}]",
                g.name
            );
        }
    }
}

// ---------------------------------------------------------------------
// Soundness on every tier-1 request: random concrete simulation never
// escapes the abstract results, probabilities are bounded and exact on
// inputs/constants, and no tier-1 design trips an Error-severity code.
// ---------------------------------------------------------------------
#[test]
fn tier1_concrete_values_lie_within_abstract_values() {
    let eng = SynthEngine::new(EngineConfig::default());
    for req in tier1_requests(8) {
        let (report, art, _) = eng.analyze(&req).unwrap();
        let nl = art.netlist();
        assert_eq!(report.nodes, nl.len(), "{req:?}");
        assert!(!report.denies(Severity::Error), "{req:?}: {report}");

        let out = analyze_netlist(nl, &AnalysisOptions::default());
        let ops = nl.ops();
        for i in 0..nl.len() {
            let p = out.prob[i];
            assert!((0.0..=1.0).contains(&p), "{req:?}: node {i} probability {p}");
            match ops[i] {
                OP_INPUT => assert_eq!(p, 0.5, "{req:?}: input node {i}"),
                OP_CONST0 => assert_eq!((p, out.activity[i]), (0.0, 0.0), "{req:?}: node {i}"),
                OP_CONST1 => assert_eq!((p, out.activity[i]), (1.0, 0.0), "{req:?}: node {i}"),
                _ => {}
            }
        }

        let seed = 0xAB5_0000 ^ nl.len() as u64;
        let mut rng = Rng::seed_from_u64(seed);
        if nl.is_sequential() {
            let mut sim = ClockedSim::new(nl);
            for cycle in 0..6 {
                let words: Vec<u64> =
                    (0..nl.num_inputs()).map(|_| rng.next_u64()).collect();
                let view = sim.step(&words).to_vec();
                assert_contained(
                    nl,
                    &out,
                    &view,
                    &format!("{req:?} seed {seed:#x} cycle {cycle}"),
                );
            }
        } else {
            let mut sim = Simulator::new();
            for round in 0..4 {
                let words: Vec<u64> =
                    (0..nl.num_inputs()).map(|_| rng.next_u64()).collect();
                let view = sim.run(nl, &words).to_vec();
                assert_contained(
                    nl,
                    &out,
                    &view,
                    &format!("{req:?} seed {seed:#x} round {round}"),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Worker-count independence: the analysis is byte-identical for any
// worker count (the 16×16 AND-array PPG rank has exactly 256 gates in
// one level, which is the parallel-schedule threshold).
// ---------------------------------------------------------------------
#[test]
fn worker_count_never_changes_the_analysis() {
    let design = MultiplierSpec::new(16).build().unwrap();
    let runs: Vec<AnalysisOutcome> = [1usize, 2, 4, 7]
        .iter()
        .map(|&workers| {
            analyze_design(&design, &AnalysisOptions { workers, ..AnalysisOptions::default() })
        })
        .collect();
    let baseline = runs[0].report.to_json().render();
    for (k, run) in runs.iter().enumerate().skip(1) {
        assert_eq!(run.ternary, runs[0].ternary, "workers run {k}");
        assert_eq!(run.prob, runs[0].prob, "workers run {k}: probabilities must be bitwise equal");
        assert_eq!(run.activity, runs[0].activity, "workers run {k}");
        assert_eq!(run.report.to_json().render(), baseline, "workers run {k}");
    }
}

// ---------------------------------------------------------------------
// Exact-code fixtures.
// ---------------------------------------------------------------------
#[test]
fn proven_constant_output_is_ufo401() {
    let mut nl = Netlist::new("const_out");
    let x = nl.input("x");
    let zero = nl.constant(false);
    let y = nl.and2(zero, x);
    nl.output("y", y);
    let out = analyze_netlist(&nl, &AnalysisOptions::default());
    assert_eq!(codes(&out.report), vec!["UFO401"], "{}", out.report);
    assert_eq!(out.report.diagnostics[0].locus, Locus::Output(0));
    assert!(out.report.diagnostics[0].message.contains("proven constant 0"));
    assert_eq!((out.report.groups[0].lo, out.report.groups[0].hi), (0, 0));
}

#[test]
fn dead_register_behind_const0_enable_chain_is_ufo402_and_ufo403() {
    // The enable is constant only *transitively* (and2 of const-0), so the
    // structural UFO301 cannot see it — the ternary domain must.
    let mut nl = Netlist::new("dead_reg");
    let x = nl.input("x");
    let d = nl.input("d");
    let zero = nl.constant(false);
    let en = nl.and2(zero, x);
    let q = nl.reg(d, en, zero, false);
    nl.output("q", q);
    assert!(lint_netlist(&nl, &LintOptions::default()).is_empty(), "not a structural finding");
    let out = analyze_netlist(&nl, &AnalysisOptions::default());
    assert_eq!(codes(&out.report), vec!["UFO402", "UFO403"], "{}", out.report);
    for diag in &out.report.diagnostics {
        assert_eq!(diag.locus, Locus::Node(q.0), "proof locus is the register");
    }
    assert_eq!(out.report.max_severity(), Some(Severity::Error));
}

#[test]
fn unreachable_carry_column_is_ufo404() {
    // A 1-bit adder whose declared sum width has one spare column: the
    // top bit can never carry, and the interval proves it.
    let mut nl = Netlist::new("capped");
    let a = nl.input("a");
    let b = nl.input("b");
    let zero = nl.constant(false);
    let s0 = nl.xor2(a, b);
    let s1 = nl.and2(a, b);
    let s2 = nl.and2(zero, a);
    nl.output("s0", s0);
    nl.output("s1", s1);
    nl.output("s2", s2);
    let out = analyze_netlist(&nl, &AnalysisOptions::default());
    assert_eq!(codes(&out.report), vec!["UFO404"], "{}", out.report);
    assert_eq!(out.report.diagnostics[0].locus, Locus::Output(2));
    assert!(out.report.diagnostics[0].message.contains("top 1 bit(s)"));
    let g = &out.report.groups[0];
    assert_eq!((g.name.as_str(), g.bits, g.lo, g.hi), ("s", 3, 0, 3));
}

// ---------------------------------------------------------------------
// Regression: a netlist the structural pass flags as UFO301 (directly
// tied const-0 enable) is independently caught by the ternary domain,
// with a proof locus on the register.
// ---------------------------------------------------------------------
#[test]
fn ufo301_netlist_is_also_caught_by_the_ternary_domain() {
    let mut nl = Netlist::new("tied_enable");
    let d = nl.input("d");
    let clr = nl.input("clr");
    let zero = nl.constant(false);
    let q = nl.reg(d, zero, clr, true);
    nl.output("q", q);
    let structural: Vec<_> =
        lint_netlist(&nl, &LintOptions::default()).iter().map(|d| d.code).collect();
    assert_eq!(structural, vec!["UFO301"]);
    let out = analyze_netlist(&nl, &AnalysisOptions::default());
    let semantic = codes(&out.report);
    assert!(semantic.contains(&"UFO403"), "{}", out.report);
    let stuck = out.report.diagnostics.iter().find(|d| d.code == "UFO403").unwrap();
    assert_eq!(stuck.locus, Locus::Node(q.0), "proof locus is the register");
    // The state itself is pinned too: q only ever holds its init value.
    assert!(semantic.contains(&"UFO402"), "{}", out.report);
}

// ---------------------------------------------------------------------
// Static vs measured activity, combinational: on a 2-bit ripple adder
// the windowed Parker–McCluskey propagation at depth 4 tracks the
// measured toggle rates to within sampling noise.
// ---------------------------------------------------------------------
#[test]
fn static_activity_tracks_measured_toggles_on_a_small_adder() {
    let mut nl = Netlist::new("adder2");
    let a0 = nl.input("a0");
    let a1 = nl.input("a1");
    let b0 = nl.input("b0");
    let b1 = nl.input("b1");
    let s0 = nl.xor2(a0, b0);
    let c0 = nl.and2(a0, b0);
    let t1 = nl.xor2(a1, b1);
    let s1 = nl.xor2(t1, c0);
    let g1 = nl.and2(a1, b1);
    let p1 = nl.and2(t1, c0);
    let c1 = nl.or2(g1, p1);
    nl.output("s0", s0);
    nl.output("s1", s1);
    nl.output("c1", c1);
    let opts = AnalysisOptions { correlation_depth: 4, ..AnalysisOptions::default() };
    let stat = static_activity(&nl, &opts);
    let meas = toggle_activity(&nl, 256, 0x7066);
    for i in nl.num_inputs()..nl.len() {
        assert!(
            (stat[i] - meas[i]).abs() < 0.05,
            "node {i}: static {:.4} vs measured {:.4}",
            stat[i],
            meas[i]
        );
    }
}

// ---------------------------------------------------------------------
// Static vs measured activity, sequential: `sim::toggle_activity` on a
// sequential netlist runs the multi-cycle clocked sweep (it used to be
// meaningless there), and both it and the static estimate put a
// free-running register pipeline at activity ≈ 0.5.
// ---------------------------------------------------------------------
#[test]
fn clocked_toggle_sweep_matches_static_estimate_on_a_register_chain() {
    let mut nl = Netlist::new("regchain");
    let x = nl.input("x");
    let one = nl.constant(true);
    let zero = nl.constant(false);
    let q1 = nl.reg(x, one, zero, false);
    let q2 = nl.reg(q1, one, zero, false);
    nl.output("q", q2);
    assert!(nl.is_sequential());
    let meas = toggle_activity(&nl, 128, 0x5eed);
    let stat = static_activity(&nl, &AnalysisOptions::default());
    for id in [q1, q2] {
        let i = id.index();
        assert!((meas[i] - 0.5).abs() < 0.05, "measured register activity {:.4}", meas[i]);
        assert!((stat[i] - 0.5).abs() < 1e-9, "static register activity {:.4}", stat[i]);
    }
}
