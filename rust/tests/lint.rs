//! Tier-1 integration tests of the static-analysis subsystem: each
//! deliberately broken fixture must yield its exact diagnostic code, every
//! tier-1 design family × operand format must lint clean end-to-end, and
//! the engine's lint gate must reject a malformed candidate before any
//! simulation is paid for.

use ufo_mac::api::{tier1_requests, DesignRequest, EngineConfig, SynthEngine};
use ufo_mac::cpa::{PrefixGraph, NONE};
use ufo_mac::ct::StagePlan;
use ufo_mac::ir::{CellKind, Netlist};
use ufo_mac::lint::{check_plan, check_prefix, lint_netlist, LintOptions, Locus, Severity};
use ufo_mac::multiplier::MultiplierSpec;

fn codes(diags: &[ufo_mac::lint::Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.code).collect()
}

#[test]
fn combinational_cycle_is_ufo001() {
    let mut nl = Netlist::new("cycle");
    let a = nl.input("a");
    let _b = nl.input("b");
    // Node 2 names itself as a fan-in: in the append-only topological IR a
    // non-earlier reference *is* a cycle.
    let g = nl.push_raw(CellKind::And2.opcode() as u8, [a.0, 2, 0]);
    nl.output("y", g);
    let diags = lint_netlist(&nl, &LintOptions::default());
    assert_eq!(codes(&diags), vec!["UFO001"], "{diags:?}");
    assert_eq!(diags[0].locus, Locus::Node(2));
}

#[test]
fn dangling_fanin_is_ufo002() {
    let mut nl = Netlist::new("dangling");
    let a = nl.input("a");
    let g = nl.push_raw(CellKind::And2.opcode() as u8, [a.0, 7, 0]);
    nl.output("y", g);
    let diags = lint_netlist(&nl, &LintOptions::default());
    assert_eq!(codes(&diags), vec!["UFO002"], "{diags:?}");
}

#[test]
fn duplicate_output_name_is_ufo004() {
    let mut nl = Netlist::new("dup");
    let a = nl.input("a");
    let b = nl.input("b");
    nl.output("y", a);
    nl.output("y", b);
    let diags = lint_netlist(&nl, &LintOptions::default());
    assert_eq!(codes(&diags), vec!["UFO004"], "{diags:?}");
}

#[test]
fn weight_leaking_ct_stage_is_ufo101() {
    // One stage of full adders over populations [3,3,3]: the top column's
    // carry leaves the declared width — weight is not conserved.
    let plan = StagePlan { f: vec![vec![1, 1, 1]], h: vec![vec![0, 0, 0]] };
    let diags = check_plan(&[3, 3, 3], &plan);
    assert_eq!(codes(&diags), vec!["UFO101"], "{diags:?}");
}

#[test]
fn gapped_prefix_graph_is_ufo104() {
    // Roots for bits 0, 1 and 3 but none for bit 2: coverage gap.
    let mut g = PrefixGraph::leaves(4);
    let n10 = g.combine(1, 0);
    g.roots[1] = n10;
    let n32 = g.combine(3, 2);
    let n30 = g.combine(n32, n10);
    g.roots[3] = n30;
    assert_eq!(g.roots[2], NONE);
    let diags = check_prefix(&g);
    assert_eq!(codes(&diags), vec!["UFO104"], "{diags:?}");
    assert_eq!(diags[0].locus, Locus::Bit(2));
}

#[test]
fn tier1_families_and_formats_lint_clean() {
    // The same list `ufo-mac lint` sweeps: every CT architecture, both
    // accumulator modes, Booth-4, across unsigned/signed square and
    // rectangular operand formats. Fresh compiles run the full structural
    // + datapath sweep over the build's own trace.
    let eng = SynthEngine::new(EngineConfig::default());
    for req in tier1_requests(8) {
        let (report, art, _) = eng.lint(&req).unwrap();
        assert!(report.is_clean(), "{req:?}: {report}");
        assert!(art.lint.as_ref().unwrap().is_clean());
    }
}

#[test]
fn forward_register_control_is_ufo302() {
    let mut nl = Netlist::new("seq_loop");
    let a = nl.input("a");
    let clr = nl.input("clr");
    // Enable pin names the register itself: the edge's own update would
    // gate the edge — a combinational loop through the control path.
    let q = nl.reg_raw(a.0, 2, clr.0, false);
    nl.output("q", q);
    let diags = lint_netlist(&nl, &LintOptions::default());
    assert_eq!(codes(&diags), vec!["UFO302"], "{diags:?}");
    assert_eq!(diags[0].locus, Locus::Node(q.0));
}

#[test]
fn unclocked_const0_enable_is_ufo301() {
    let mut nl = Netlist::new("seq_unclocked");
    let a = nl.input("a");
    let zero = nl.constant(false);
    let clr = nl.input("clr");
    let q = nl.reg(a, zero, clr, true);
    nl.output("q", q);
    let diags = lint_netlist(&nl, &LintOptions::default());
    assert_eq!(codes(&diags), vec!["UFO301"], "{diags:?}");
}

#[test]
fn dangling_register_pins_are_ufo002_per_pin() {
    let mut nl = Netlist::new("seq_dangle");
    let _clr = nl.input("clr");
    // d and en both point past the end of the netlist; clr is the input.
    let q = nl.reg_raw(7, 9, 0, false);
    nl.output("q", q);
    let diags = lint_netlist(&nl, &LintOptions::default());
    assert_eq!(codes(&diags), vec!["UFO002", "UFO002"], "{diags:?}");
}

#[test]
fn imbalanced_stage_cut_is_ufo303_pedantic_info() {
    let mut nl = Netlist::new("seq_imbalance");
    let a = nl.input("a");
    let b = nl.input("b");
    let en = nl.input("en");
    let clr = nl.input("clr");
    // One register closes a 6-deep XOR chain, the other a single gate:
    // the clock period is set by the deep segment while the shallow
    // rank's slack idles.
    let mut deep = a;
    for _ in 0..6 {
        deep = nl.xor2(deep, b);
    }
    let q_deep = nl.reg(deep, en, clr, false);
    let shallow = nl.and2(a, b);
    let q_shallow = nl.reg(shallow, en, clr, false);
    let y = nl.or2(q_deep, q_shallow);
    nl.output("y", y);
    nl.validate().unwrap();
    let clean = lint_netlist(&nl, &LintOptions::default());
    assert!(clean.is_empty(), "stage balance is pedantic-only: {clean:?}");
    let diags = lint_netlist(&nl, &LintOptions { pedantic: true });
    let seq: Vec<_> = diags.iter().filter(|d| d.code == "UFO303").collect();
    assert_eq!(seq.len(), 1, "{diags:?}");
    assert_eq!(seq[0].locus, Locus::Node(q_shallow.0));
    assert_eq!(seq[0].severity, Severity::Info);
}

#[test]
fn tier1_sweep_carries_pipelined_variants() {
    // The clean-sweep test above runs these through the engine's lint
    // path; this pins that the sweep actually contains the sequential
    // coverage (a 1-stage multiplier + 2-stage fused MACs, both
    // signednesses) so a regression cannot silently drop it.
    let reqs = tier1_requests(8);
    let staged: Vec<usize> = reqs
        .iter()
        .filter_map(|r| match r {
            DesignRequest::Multiplier(m) if m.pipeline_stages > 0 => Some(m.pipeline_stages),
            _ => None,
        })
        .collect();
    assert_eq!(staged, [1, 2, 2], "tier-1 pipelined variants");
}

#[test]
fn engine_gate_rejects_malformed_candidate_without_simulation() {
    // verify_vectors is configured, but the infeasible plan must die in
    // the lint pre-check — the error carries the diagnostic code, and the
    // equivalence sweep (which would dominate the runtime) never runs.
    let eng = SynthEngine::new(EngineConfig { verify_vectors: 1 << 16, ..Default::default() });
    let plan = StagePlan { f: vec![vec![9, 0, 0]], h: vec![vec![0, 0, 0]] };
    let req = DesignRequest::from_spec(&MultiplierSpec::new(2).with_plan(plan));
    let err = format!("{:#}", eng.compile(&req).unwrap_err());
    assert!(err.contains("UFO1"), "{err}");
}
