//! Minimal benchmark harness (criterion is not vendored in this image).
//!
//! Provides warmup + repeated timed runs with mean/median/min and a
//! machine-readable JSON line per benchmark, so `cargo bench` output can be
//! captured into `bench_output.txt` and EXPERIMENTS.md the same way a
//! criterion run would be. Every result is also collected in memory;
//! [`Bench::finish`] writes the whole suite to `BENCH_<suite>.json` so the
//! perf trajectory is machine-readable without scraping stdout.

use crate::util::Json;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One measured statistic set, in nanoseconds.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Mean sample time (ns).
    pub mean_ns: f64,
    /// Median sample time (ns).
    pub median_ns: f64,
    /// Fastest sample (ns).
    pub min_ns: f64,
    /// Slowest sample (ns).
    pub max_ns: f64,
    /// Number of samples taken.
    pub samples: usize,
}

impl Stats {
    fn from_samples(mut ns: Vec<f64>) -> Stats {
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        Stats {
            mean_ns: ns.iter().sum::<f64>() / n as f64,
            median_ns: ns[n / 2],
            min_ns: ns[0],
            max_ns: ns[n - 1],
            samples: n,
        }
    }
}

fn fmt_time(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner. Each `cargo bench` target constructs one of these.
pub struct Bench {
    suite: String,
    /// Target per-benchmark measurement budget.
    pub budget: Duration,
    /// Max sample count per benchmark.
    pub max_samples: usize,
    /// Collected result records, flushed by [`Bench::finish`].
    results: Mutex<Vec<Json>>,
}

impl Bench {
    /// Runner for one bench suite (honours `UFO_BENCH_QUICK` for CI-style
    /// smoke runs).
    pub fn new(suite: impl Into<String>) -> Self {
        let quick = std::env::var("UFO_BENCH_QUICK").is_ok();
        Bench {
            suite: suite.into(),
            budget: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_samples: if quick { 5 } else { 30 },
            results: Mutex::new(Vec::new()),
        }
    }

    /// Time `f` repeatedly; prints one human line + one JSON line.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        let mut warm = 0;
        while t0.elapsed() < self.budget / 10 && warm < 3 {
            std::hint::black_box(f());
            warm += 1;
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_samples
            && (samples.len() < 3 || start.elapsed() < self.budget)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64() * 1e9);
        }
        let stats = Stats::from_samples(samples);
        println!(
            "bench {}/{name}: mean {} median {} min {} ({} samples)",
            self.suite,
            fmt_time(stats.mean_ns),
            fmt_time(stats.median_ns),
            fmt_time(stats.min_ns),
            stats.samples
        );
        let record = Json::obj(vec![
            ("suite", Json::str(self.suite.clone())),
            ("name", Json::str(name)),
            ("mean_ns", Json::num(stats.mean_ns)),
            ("median_ns", Json::num(stats.median_ns)),
            ("min_ns", Json::num(stats.min_ns)),
            ("samples", Json::num(stats.samples as f64)),
        ]);
        println!("BENCH_JSON {}", record.render());
        self.results.lock().unwrap().push(record);
        stats
    }

    /// Report a scalar metric (area, delay, R², …) rather than a time — the
    /// figure/table benches are metric reproductions, not microbenchmarks.
    pub fn metric(&self, name: &str, value: f64, unit: &str) {
        println!("metric {}/{name}: {value:.6} {unit}", self.suite);
        let record = Json::obj(vec![
            ("suite", Json::str(self.suite.clone())),
            ("name", Json::str(name)),
            ("value", Json::num(value)),
            ("unit", Json::str(unit)),
        ]);
        println!("BENCH_JSON {}", record.render());
        self.results.lock().unwrap().push(record);
    }

    /// Flush every collected record to `BENCH_<suite>.json` in the current
    /// directory (one JSON document: `{"suite": …, "results": […]}`), so
    /// the perf trajectory is machine-readable without scraping stdout.
    /// Returns the written path.
    pub fn finish(&self) -> std::io::Result<PathBuf> {
        let records: Vec<Json> = self.results.lock().unwrap().drain(..).collect();
        let doc = Json::obj(vec![
            ("suite", Json::str(self.suite.clone())),
            ("results", Json::arr(records)),
        ]);
        let path = PathBuf::from(format!("BENCH_{}.json", self.suite));
        std::fs::write(&path, doc.render())?;
        println!("bench {}: wrote {}", self.suite, path.display());
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 3.0);
        assert_eq!(s.median_ns, 2.0);
        assert!((s.mean_ns - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(500.0).contains("ns"));
        assert!(fmt_time(5_000.0).contains("µs"));
        assert!(fmt_time(5_000_000.0).contains("ms"));
        assert!(fmt_time(5e9).contains(" s"));
    }

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("UFO_BENCH_QUICK", "1");
        let b = Bench::new("test");
        let s = b.bench("noop", || 1 + 1);
        assert!(s.samples >= 3);
        assert!(s.min_ns >= 0.0);
    }

    #[test]
    fn finish_writes_machine_readable_suite_file() {
        std::env::set_var("UFO_BENCH_QUICK", "1");
        let b = Bench::new("unittest_suite");
        b.bench("noop", || 2 + 2);
        b.metric("answer", 42.0, "units");
        let written = b.finish().unwrap();
        assert_eq!(written, std::path::PathBuf::from("BENCH_unittest_suite.json"));
        let text = std::fs::read_to_string(&written).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("suite").and_then(|s| s.as_str()), Some("unittest_suite"));
        let results = doc.get("results").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(results.len(), 2);
        assert!(results[0].get("mean_ns").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        assert_eq!(results[1].get("value").and_then(|v| v.as_f64()), Some(42.0));
        std::fs::remove_file(&written).ok();
    }
}
