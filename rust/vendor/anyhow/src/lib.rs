//! Offline shim for the `anyhow` crate.
//!
//! The build image vendors no registry crates, so this package implements
//! the subset of anyhow's API that the `ufo_mac` crate uses: the erased
//! [`Error`] type with context chaining, the [`Result`] alias, the
//! [`anyhow!`], [`bail!`] and [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`. Semantics match the real
//! crate for these uses; downcasting and backtraces are not provided.

use std::fmt;

/// Dynamically typed error with an optional chain of context messages.
pub struct Error {
    msg: String,
    /// Outermost context first, like anyhow's `{:#}` rendering.
    context: Vec<String>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), context: Vec::new() }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Self {
        self.context.push(ctx.to_string());
        self
    }

    fn render(&self, f: &mut fmt::Formatter<'_>, chain: bool) -> fmt::Result {
        match self.context.last() {
            None => write!(f, "{}", self.msg)?,
            Some(outer) => {
                write!(f, "{outer}")?;
                if chain {
                    for c in self.context.iter().rev().skip(1) {
                        write!(f, ": {c}")?;
                    }
                    write!(f, ": {}", self.msg)?;
                }
            }
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` shows the outermost message; `{:#}` shows the full chain.
        self.render(f, f.alternate())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.render(f, true)
    }
}

// Like the real crate: any std error converts via `?`. `Error` itself does
// not implement `std::error::Error`, which keeps this impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` to `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(ctx))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, format string, or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn display_and_chain() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn ensure_macro() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert!(check(3).is_ok());
        assert!(check(30).is_err());
    }
}
